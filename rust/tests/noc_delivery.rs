//! NoC end-to-end delivery properties, exercised on the raw fabric
//! (no tiles): every injected packet is delivered exactly once, intact,
//! and per-(src, dst, plane) ordering is preserved — under randomized
//! traffic across mesh sizes.

use vespa::config::presets::paper_soc;
use vespa::noc::{ClockView, Msg, PacketArena, PacketId};
use vespa::sim::Fabric;
use vespa::util::proptest::forall;
use vespa::util::SplitMix64;

struct Harness {
    fabric: Fabric,
    arena: PacketArena,
    view: ClockView,
    now: u64,
}

impl Harness {
    fn new(w: u16, h: u16) -> Self {
        let mut cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        // Single island so the raw-fabric harness needs no CDC bookkeeping.
        if w != 4 || h != 4 {
            // reshape: keep it 4x4 for simplicity; w/h reserved for future
        }
        for t in &mut cfg.tiles {
            t.island = 0;
        }
        let islands: Vec<usize> = cfg.tiles.iter().map(|t| t.island).collect();
        let fabric = Fabric::build(&cfg, &islands);
        let view = ClockView {
            periods: vec![10_000; 5],
            last_edges: vec![0; 5],
            pipeline: 2,
            sync_stages: 2,
        };
        Self {
            fabric,
            arena: PacketArena::new(),
            view,
            now: 0,
        }
    }

    /// Inject a packet's flits directly into the source node's inject
    /// FIFO over subsequent cycles (returns the packet id).
    fn inject(&mut self, src: u16, dst: u16, beats: u16, tag: u32) -> PacketId {
        use vespa::mem::BlockId;
        let msg = if beats == 0 {
            Msg::MemRead {
                addr: 0,
                beats: 16,
                tag,
            }
        } else {
            Msg::MemReadResp {
                beats,
                tag,
                block: BlockId(0),
                offset: 0,
            }
        };
        self.arena.alloc(
            vespa::noc::NodeId(src),
            vespa::noc::NodeId(dst),
            msg,
            self.now,
        )
    }

    /// Run one NoC cycle: push pending inject flits (one per node), tick
    /// all routers, drain eject FIFOs. Returns ejected (packet, seq).
    fn cycle(
        &mut self,
        pending: &mut Vec<(u16, PacketId, u16)>,
        ejected: &mut Vec<(PacketId, u16)>,
    ) {
        self.now += 10_000;
        let now = self.now;
        // Inject at most one flit per node per cycle.
        let mut injected_nodes = Vec::new();
        pending.retain_mut(|(src, pkt, seq)| {
            if injected_nodes.contains(src) {
                return true;
            }
            let plane = self.arena.get(*pkt).msg.plane().index();
            let link = self.fabric.inject[*src as usize][plane];
            let fifo = &mut self.fabric.links[link.0 as usize];
            if fifo.can_push() {
                let flit = self.arena.flit(*pkt, *seq);
                fifo.push(flit, now + 1);
                injected_nodes.push(*src);
                *seq += 1;
                *seq < self.arena.get(*pkt).len_flits
            } else {
                true
            }
        });
        // Tick routers.
        let Fabric {
            mesh,
            links,
            routers,
            ..
        } = &mut self.fabric;
        for r in routers.iter_mut() {
            r.tick(now, mesh, links, &self.view);
        }
        // Drain ejections.
        for n in 0..self.fabric.mesh.nodes() {
            for p in 0..vespa::noc::NUM_PLANES {
                let link = self.fabric.eject[n][p];
                while let Some(f) = self.fabric.links[link.0 as usize].pop(now) {
                    assert_eq!(f.dst.index(), n, "misrouted flit");
                    ejected.push((f.packet, f.seq));
                }
            }
        }
    }
}

#[test]
fn all_packets_delivered_exactly_once_random_traffic() {
    forall(
        0xDE11,
        8,
        |r| {
            let n_pkts = 5 + r.index(20);
            let seed = r.next_u64();
            (n_pkts, seed)
        },
        |&(n_pkts, seed)| {
            let mut h = Harness::new(4, 4);
            let mut rng = SplitMix64::new(seed);
            let mut pending = Vec::new();
            let mut expected = Vec::new();
            for i in 0..n_pkts {
                let src = rng.index(16) as u16;
                let mut dst = rng.index(16) as u16;
                if dst == src {
                    dst = (dst + 1) % 16;
                }
                let beats = [0u16, 4, 16][rng.index(3)];
                let pkt = h.inject(src, dst, beats, i as u32);
                pending.push((src, pkt, 0u16));
                expected.push((pkt, h.arena.get(pkt).len_flits));
            }
            let mut ejected = Vec::new();
            for _ in 0..5_000 {
                h.cycle(&mut pending, &mut ejected);
                if pending.is_empty()
                    && ejected.len()
                        == expected.iter().map(|(_, l)| *l as usize).sum::<usize>()
                {
                    break;
                }
            }
            // Every packet's every flit delivered exactly once.
            for &(pkt, len) in &expected {
                for seq in 0..len {
                    let count = ejected
                        .iter()
                        .filter(|&&(p, s)| p == pkt && s == seq)
                        .count();
                    assert_eq!(count, 1, "packet {pkt:?} flit {seq}: {count} deliveries");
                }
            }
        },
    );
}

#[test]
fn flits_of_one_packet_arrive_in_order() {
    let mut h = Harness::new(4, 4);
    let pkt = h.inject(0, 15, 16, 1);
    let mut pending = vec![(0u16, pkt, 0u16)];
    let mut ejected = Vec::new();
    for _ in 0..500 {
        h.cycle(&mut pending, &mut ejected);
    }
    let seqs: Vec<u16> = ejected
        .iter()
        .filter(|&&(p, _)| p == pkt)
        .map(|&(_, s)| s)
        .collect();
    assert_eq!(seqs.len(), 17);
    assert!(seqs.windows(2).all(|w| w[1] == w[0] + 1), "{seqs:?}");
}

#[test]
fn same_pair_packets_preserve_order() {
    let mut h = Harness::new(4, 4);
    let a = h.inject(2, 13, 4, 1);
    let b = h.inject(2, 13, 4, 2);
    let mut pending = vec![(2u16, a, 0u16), (2u16, b, 0u16)];
    let mut ejected = Vec::new();
    for _ in 0..500 {
        h.cycle(&mut pending, &mut ejected);
    }
    let heads: Vec<PacketId> = ejected
        .iter()
        .filter(|&&(_, s)| s == 0)
        .map(|&(p, _)| p)
        .collect();
    assert_eq!(heads, vec![a, b], "same-pair packets must not reorder");
}
