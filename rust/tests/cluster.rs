//! System tests for the `cluster` subsystem — the ISSUE's acceptance
//! criteria: (a) bit-identical replay from the same seed + spec,
//! (b) a 4-replica fleet sustains >= 3x the achieved rps of a single
//! SoC at the same SLO attainment, (c) the autoscaler meets an SLO a
//! fixed minimum fleet misses while finishing with fewer
//! replica-seconds than a fixed maximum fleet — plus fleet-wide drop
//! accounting and spec validation.

use vespa::cluster::{AutoscaleSpec, ClusterSpec};
use vespa::config::SocConfig;
use vespa::scenario::{ms, Scenario};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};

/// One 2-replica dfmul tile on a governable island — the per-replica
/// SoC every fleet slot clones. At 50 MHz the tile serves ~4250 req/s
/// (42.5 req/s per MHz per replica), so fleet size is the only
/// capacity knob the cluster layer controls.
fn fleet_cfg(accel_mhz: u64) -> SocConfig {
    Scenario::grid(2, 2)
        .name("cluster-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", accel_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

// ---------------------------------------------------------------------
// (a) Deterministic replay.
// ---------------------------------------------------------------------

#[test]
fn same_seed_spec_and_fleet_replay_identically() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 5000.0 }, ms(60))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0xABCD);
    let cspec = ClusterSpec::new(3, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .autoscale(AutoscaleSpec::new(1));
    let r1 = cspec.run(fleet_cfg(50)).unwrap();
    let r2 = cspec.run(fleet_cfg(50)).unwrap();
    assert!(r1.completed > 20, "enough traffic to be meaningful");
    assert_eq!(r1, r2, "same seed + spec + config => identical ClusterReport");

    let other = ClusterSpec {
        spec: cspec.spec.clone().seed(0x1234),
        ..cspec.clone()
    };
    let r3 = other.run(fleet_cfg(50)).unwrap();
    assert_ne!(r1, r3, "a different seed is a different run");
}

// ---------------------------------------------------------------------
// (b) Fleet scaling: 4 replicas >= 3x one SoC's achieved rps.
// ---------------------------------------------------------------------

#[test]
fn four_replicas_triple_single_soc_throughput() {
    // 16000 req/s against a ~4250 req/s SoC: a single replica saturates
    // and sheds most of the load, while a 4-slot fleet splits it into
    // ~4000 req/s per replica — inside each box's capacity.
    let spec = ServeSpec::new(Arrival::Poisson { rps: 16_000.0 }, ms(100))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(20))
        .seed(0xF1EE);
    let single = ClusterSpec::new(1, spec.clone()).run(fleet_cfg(50)).unwrap();
    let fleet4 = ClusterSpec::new(4, spec).run(fleet_cfg(50)).unwrap();

    assert_eq!(single.offered, fleet4.offered, "equal offered load");
    assert!(single.completed > 100 && fleet4.completed > 400);
    assert!(
        fleet4.achieved_rps >= 3.0 * single.achieved_rps,
        "fleet {:.0} rps vs single {:.0} rps",
        fleet4.achieved_rps,
        single.achieved_rps
    );
    // "At the same SLO attainment": scaling out must not trade
    // throughput for tail quality.
    assert!(
        fleet4.slo_attainment >= single.slo_attainment,
        "fleet attainment {:.3} vs single {:.3}",
        fleet4.slo_attainment,
        single.slo_attainment
    );
}

// ---------------------------------------------------------------------
// (c) Autoscaler: meets an SLO the fixed minimum misses, for fewer
//     replica-seconds than the fixed maximum.
// ---------------------------------------------------------------------

#[test]
fn autoscaler_meets_slo_cheaper_than_fixed_max() {
    let slo = ms(5);
    let spec = ServeSpec::new(Arrival::Poisson { rps: 6000.0 }, ms(200))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(slo)
        .sample_interval(ms(2))
        .seed(0x50C);

    // Fixed minimum: one ~4250 req/s SoC against 6000 offered — the
    // queue pegs at capacity and the p95 tail sits past the SLO.
    let r_min = ClusterSpec::new(1, spec.clone()).run(fleet_cfg(50)).unwrap();
    assert_eq!(
        r_min.slo_met,
        Some(false),
        "fixed-min p95 {:.3} ms",
        r_min.latency.p95_ms()
    );

    // Fixed maximum: four replicas meet the SLO trivially but stay
    // active (and billed) for the whole run.
    let r_max = ClusterSpec::new(4, spec.clone()).run(fleet_cfg(50)).unwrap();
    assert_eq!(r_max.slo_met, Some(true));
    assert_eq!(r_max.final_active, 4);

    // Autoscaled: starts at the fixed minimum, grows only while the
    // SLO demands it.
    let r_auto = ClusterSpec::new(4, spec)
        .autoscale(AutoscaleSpec::new(1))
        .run(fleet_cfg(50))
        .unwrap();
    assert_eq!(
        r_auto.slo_met,
        Some(true),
        "autoscaled p95 {:.3} ms (actions {:?})",
        r_auto.latency.p95_ms(),
        r_auto.autoscale_actions
    );
    assert!(!r_auto.autoscale_actions.is_empty(), "the autoscaler acted");
    assert!(
        r_auto.replica_seconds < 0.8 * r_max.replica_seconds,
        "autoscaled {:.4} replica-seconds vs fixed-max {:.4}",
        r_auto.replica_seconds,
        r_max.replica_seconds
    );
}

// ---------------------------------------------------------------------
// Fleet-wide accounting.
// ---------------------------------------------------------------------

#[test]
fn accounting_invariants_hold_fleet_wide() {
    // Tiny queues in front of slow replicas under heavy load: the
    // balancer must spill once every replica is full, and every request
    // must be accounted for exactly once.
    let spec = ServeSpec::new(Arrival::Poisson { rps: 4000.0 }, ms(50))
        .queue_capacity(2)
        .seed(3);
    let r = ClusterSpec::new(2, spec).run(fleet_cfg(10)).unwrap();
    assert!(r.spilled > 0, "overload must spill at the balancer");
    assert_eq!(r.admitted + r.dropped, r.offered);
    assert_eq!(r.completed + r.unfinished, r.admitted);
    let repl_admitted: u64 = r.per_replica.iter().map(|p| p.admitted).sum();
    let repl_completed: u64 = r.per_replica.iter().map(|p| p.completed).sum();
    let repl_dropped: u64 = r.per_replica.iter().map(|p| p.dropped).sum();
    assert_eq!(repl_admitted, r.admitted);
    assert_eq!(repl_completed, r.completed);
    assert_eq!(r.spilled + repl_dropped, r.dropped);
    assert!(r.replica_seconds > 0.0);
    assert!(!r.active_replicas.samples.is_empty());
    assert_eq!(r.per_replica.len(), 2);
}

// ---------------------------------------------------------------------
// Validation.
// ---------------------------------------------------------------------

#[test]
fn closed_loop_arrivals_are_rejected() {
    let spec = ServeSpec::new(
        Arrival::ClosedLoop {
            clients: 3,
            think: ms(1),
        },
        ms(10),
    );
    let err = ClusterSpec::new(2, spec)
        .run(fleet_cfg(50))
        .unwrap_err()
        .to_string();
    assert!(err.contains("open-loop"), "unexpected error: {err}");
}

#[test]
fn spec_bounds_are_validated() {
    let spec = || ServeSpec::new(Arrival::Poisson { rps: 100.0 }, ms(10));

    let err = ClusterSpec::new(0, spec()).run(fleet_cfg(50)).unwrap_err();
    assert!(err.to_string().contains("replicas"), "{err}");
    let err = ClusterSpec::new(65, spec()).run(fleet_cfg(50)).unwrap_err();
    assert!(err.to_string().contains("replicas"), "{err}");

    // Autoscale floor above the fleet ceiling.
    let err = ClusterSpec::new(2, spec().slo(ms(5)))
        .autoscale(AutoscaleSpec::new(3))
        .run(fleet_cfg(50))
        .unwrap_err();
    assert!(err.to_string().contains("min_replicas"), "{err}");

    // Autoscaling needs an SLO to scale against.
    let err = ClusterSpec::new(2, spec())
        .autoscale(AutoscaleSpec::new(1))
        .run(fleet_cfg(50))
        .unwrap_err();
    assert!(err.to_string().to_lowercase().contains("slo"), "{err}");
}
