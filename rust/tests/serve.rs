//! System tests for the `serve` subsystem — the ISSUE's acceptance
//! criteria: (a) deterministic replay, (b) JSQ tail latency beats
//! round-robin on a replicated-accelerator SoC, (c) the queue governor
//! meets an SLO a static low frequency misses while ending below the
//! always-max frequency — plus drop accounting, closed-loop clients,
//! and trace arrivals.

use vespa::scenario::{ms, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeSpec};

/// Two single-replica dfmul tiles on independent DFS islands — the
/// "replicated accelerator across NoC nodes" scenario. Heterogeneous
/// frequencies make dispatch policy quality visible.
fn two_tile_session(fast_mhz: u64, slow_mhz: u64) -> Session {
    let cfg = Scenario::grid(2, 2)
        .name("serve-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("fast", fast_mhz, 10..=50, 5)
        .island_dfs("slow", slow_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 1, "fast")
        .accel_at(0, 1, "dfmul", 1, "slow")
        .io_at_on(1, 1, "noc")
        .build()
        .unwrap();
    Session::new(cfg).unwrap()
}

/// One 2-replica dfmul tile on a governable island (10..=50 MHz).
fn governed_session(start_mhz: u64) -> (Session, usize, usize) {
    let cfg = Scenario::grid(2, 2)
        .name("serve-governed")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", start_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .fill_tg("noc")
        .build()
        .unwrap();
    let session = Session::new(cfg).unwrap();
    let tile = session.mra_tiles()[0];
    (session, tile, 1) // island index 1 = "acc"
}

// ---------------------------------------------------------------------
// (a) Deterministic replay.
// ---------------------------------------------------------------------

#[test]
fn same_seed_and_spec_replay_identically() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 900.0 }, ms(80))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(10))
        .seed(0xABCD);
    let r1 = two_tile_session(50, 25).serve(&spec).unwrap();
    let r2 = two_tile_session(50, 25).serve(&spec).unwrap();
    assert!(r1.completed > 20, "enough traffic to be meaningful");
    assert_eq!(r1, r2, "same seed + spec => identical ServeReport");

    let r3 = two_tile_session(50, 25)
        .serve(&spec.clone().seed(0x1234))
        .unwrap();
    assert_ne!(r1, r3, "a different seed is a different run");
}

// ---------------------------------------------------------------------
// (b) JSQ p99 <= round-robin p99 at equal offered load.
// ---------------------------------------------------------------------

#[test]
fn jsq_tail_beats_round_robin_on_heterogeneous_tiles() {
    // 2000 req/s against 50 MHz + 15 MHz dfmul tiles: round-robin
    // insists on feeding the slow tile ~half the load (far past its
    // ~640 req/s capacity), so its queue pegs and the tail explodes;
    // JSQ balances by observed depth.
    let load = |policy| {
        ServeSpec::new(Arrival::Poisson { rps: 2000.0 }, ms(150))
            .policy(policy)
            .seed(0xFEED)
    };
    let rr = two_tile_session(50, 15)
        .serve(&load(DispatchPolicy::RoundRobin))
        .unwrap();
    let jsq = two_tile_session(50, 15)
        .serve(&load(DispatchPolicy::JoinShortestQueue))
        .unwrap();
    assert_eq!(rr.offered, jsq.offered, "equal offered load");
    assert!(rr.completed > 100 && jsq.completed > 100);
    assert!(
        jsq.latency.p99_ps <= rr.latency.p99_ps,
        "JSQ p99 {:.3} ms must not exceed RR p99 {:.3} ms",
        jsq.latency.p99_ms(),
        rr.latency.p99_ms()
    );
    // The gap should be structural, not noise.
    assert!(
        jsq.latency.p99_ps < 0.8 * rr.latency.p99_ps,
        "JSQ {:.3} ms vs RR {:.3} ms",
        jsq.latency.p99_ms(),
        rr.latency.p99_ms()
    );
}

#[test]
fn least_loaded_routes_by_service_rate() {
    // At equal queue depths the frequency-aware policy prefers the tile
    // that drains faster, so the fast tile must absorb well over half
    // the admitted requests.
    let spec = ServeSpec::new(Arrival::Poisson { rps: 1500.0 }, ms(100))
        .policy(DispatchPolicy::LeastLoadedTile)
        .seed(0xBEEF);
    let r = two_tile_session(50, 15).serve(&spec).unwrap();
    let fast = &r.per_tile[0]; // tile order follows ServeSpec resolution
    let slow = &r.per_tile[1];
    assert!(fast.admitted > 2 * slow.admitted, "{r:#?}");
    assert!(r.completed > 50);
}

// ---------------------------------------------------------------------
// (c) The governor meets an SLO a static low frequency misses, ending
//     below the always-max frequency.
// ---------------------------------------------------------------------

#[test]
fn queue_governor_meets_slo_static_low_misses() {
    let slo = ms(10); // p95 target
    let spec = |governed: bool, island: usize| {
        let s = ServeSpec::new(Arrival::Poisson { rps: 1200.0 }, ms(400))
            .policy(DispatchPolicy::JoinShortestQueue)
            .slo(slo)
            .sample_interval(ms(2))
            .seed(0x50C);
        if governed {
            // Boost as soon as ~one invocation per replica is queued:
            // the earlier the climb, the shorter the overloaded tail.
            s.governor(GovernorSpec {
                depth_high: 2.0,
                ..GovernorSpec::new(island, slo)
            })
        } else {
            s
        }
    };

    // Static low: 10 MHz serves ~850 req/s against 1200 offered —
    // permanently overloaded, tail far past the SLO.
    let (mut low, _tile, island) = governed_session(10);
    let r_low = low.serve(&spec(false, island)).unwrap();
    assert_eq!(r_low.slo_met, Some(false), "p95 {:.3} ms", r_low.latency.p95_ms());
    assert!(r_low.latency.p95_ps > slo as f64);

    // Always-max: meets the SLO trivially but burns 50 MHz forever.
    let (mut max, _tile, island_max) = governed_session(50);
    let r_max = max.serve(&spec(false, island_max)).unwrap();
    assert_eq!(r_max.slo_met, Some(true));
    assert_eq!(r_max.final_freq_mhz[island_max], 50);

    // Governed: starts at the same 10 MHz, boosts until the queue and
    // tail recover, relaxes when over-provisioned.
    let (mut gov, _tile, island_gov) = governed_session(10);
    let r_gov = gov.serve(&spec(true, island_gov)).unwrap();
    assert_eq!(
        r_gov.slo_met,
        Some(true),
        "governor p95 {:.3} ms vs SLO {:.1} ms (actions {:?})",
        r_gov.latency.p95_ms(),
        slo as f64 / 1e9,
        r_gov.governor_actions
    );
    assert!(!r_gov.governor_actions.is_empty(), "the governor acted");
    assert!(
        r_gov.final_freq_mhz[island_gov] < r_max.final_freq_mhz[island_max],
        "governor settled at {} MHz, below the always-max {} MHz",
        r_gov.final_freq_mhz[island_gov],
        r_max.final_freq_mhz[island_max]
    );
}

// ---------------------------------------------------------------------
// Bounded queues, closed loop, traces.
// ---------------------------------------------------------------------

#[test]
fn bounded_queues_drop_and_account_exactly() {
    // A tiny queue in front of a slow tile under heavy load: most
    // requests must be rejected, and every request must be accounted
    // for (admitted + dropped = offered; completed + unfinished =
    // admitted).
    let (mut session, tile, _island) = governed_session(10);
    let spec = ServeSpec::new(Arrival::Poisson { rps: 2000.0 }, ms(50))
        .tiles(vec![tile])
        .queue_capacity(2)
        .seed(3);
    let r = session.serve(&spec).unwrap();
    assert!(r.dropped > 0, "overload must drop");
    assert_eq!(r.admitted + r.dropped, r.offered);
    assert_eq!(r.completed + r.unfinished, r.admitted);
    assert!(r.per_tile[0].max_depth <= 2, "bounded queue respected");
    let tile_sum: u64 = r.per_tile.iter().map(|t| t.admitted).sum();
    assert_eq!(tile_sum, r.admitted);
}

#[test]
fn closed_loop_clients_self_limit() {
    let (mut session, tile, _island) = governed_session(50);
    let spec = ServeSpec::new(
        Arrival::ClosedLoop {
            clients: 3,
            think: ms(1),
        },
        ms(60),
    )
    .tiles(vec![tile])
    .seed(11);
    let r = session.serve(&spec).unwrap();
    // Three clients can never queue deeper than three.
    assert!(r.per_tile[0].max_depth <= 3, "{r:#?}");
    assert_eq!(r.dropped, 0);
    assert_eq!(r.unfinished, 0, "drain finishes the last in-flight batch");
    assert_eq!(r.completed, r.admitted);
    // Each client cycles roughly every think + service; expect dozens
    // of completions, far fewer than an open loop would force.
    assert!(r.completed > 30, "{}", r.completed);
}

#[test]
fn trace_arrivals_run_exactly() {
    let (mut session, tile, _island) = governed_session(50);
    let spec = ServeSpec::new(Arrival::Trace(vec![ms(1), ms(2), ms(3)]), ms(10))
        .tiles(vec![tile])
        .seed(999); // irrelevant for traces
    let r = session.serve(&spec).unwrap();
    assert_eq!(r.offered, 3);
    assert_eq!(r.completed, 3);
    assert_eq!(r.latency.count, 3);
    assert!(r.latency.p50_ps > 0.0);
    assert!(r.latency.max_ps >= r.latency.p99_ps);
    // Queue-depth timelines exist for the served tile.
    assert_eq!(r.queue_depth.len(), 1);
    assert!(!r.queue_depth[0].samples.is_empty());
}
