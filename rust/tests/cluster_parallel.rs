//! Parallel fleet execution: the barrier loop's bit-exactness contract.
//!
//! `ClusterSpec::threads` only changes *wall time* — for every balancer,
//! with the governor on, under autoscaling, and across mid-run DFS
//! retunes, the merged [`ClusterReport`] must be bit-identical to the
//! serial reference (`threads = 1`). These tests pin that contract for
//! `threads in {1, 2, 0 (= all cores)}`, covering both the wide-span
//! round-robin fast path and the narrow per-arrival barrier path.

use vespa::cluster::{AutoscaleSpec, ClusterReport, ClusterSpec};
use vespa::config::SocConfig;
use vespa::scenario::{ms, Scenario};
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeSpec};

/// Same per-replica SoC as `tests/cluster.rs`: one 2-replica dfmul tile
/// on a governable island (~4250 req/s at 50 MHz). Island 0 is the NoC,
/// island 1 is the DFS-capable accelerator island.
fn fleet_cfg(accel_mhz: u64) -> SocConfig {
    Scenario::grid(2, 2)
        .name("cluster-par-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", accel_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

/// Run `cspec` at each thread count and return the reports, asserting
/// every parallel report equals the serial reference bit-for-bit.
fn run_all_thread_counts(cspec: &ClusterSpec, mhz: u64) -> Vec<ClusterReport> {
    let reports: Vec<ClusterReport> = [1usize, 2, 0]
        .iter()
        .map(|&t| {
            cspec
                .clone()
                .threads(t)
                .run(fleet_cfg(mhz))
                .unwrap_or_else(|e| panic!("threads={t}: {e}"))
        })
        .collect();
    for (i, r) in reports.iter().enumerate().skip(1) {
        let t = [1usize, 2, 0][i];
        assert_eq!(
            &reports[0], r,
            "threads={t} must reproduce the serial report bit-exactly"
        );
    }
    reports
}

// ---------------------------------------------------------------------
// Every balancer, governor off: wide path (round-robin) and narrow
// paths (JSQ, least-loaded) all match serial.
// ---------------------------------------------------------------------

#[test]
fn all_balancers_agree_across_thread_counts() {
    for balancer in [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::LeastLoadedTile,
    ] {
        let spec = ServeSpec::new(Arrival::Poisson { rps: 5000.0 }, ms(50))
            .slo(ms(5))
            .sample_interval(ms(2))
            .seed(0xABCD);
        let cspec = ClusterSpec::new(3, spec).balancer(balancer);
        let reports = run_all_thread_counts(&cspec, 50);
        assert!(
            reports[0].completed > 100,
            "{balancer:?}: enough traffic to be meaningful"
        );
    }
}

// ---------------------------------------------------------------------
// Governor on: the wide round-robin path replays arrivals inside a
// whole sample window, so the governor's window must still see the
// same latency population at every sample point.
// ---------------------------------------------------------------------

#[test]
fn governor_retunes_identically_in_parallel() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 5500.0 }, ms(60))
        .slo(ms(5))
        .sample_interval(ms(2))
        .governor(GovernorSpec::new(1, ms(5)))
        .seed(0x60F);
    let cspec = ClusterSpec::new(3, spec).balancer(DispatchPolicy::RoundRobin);
    // Start at 20 MHz (~1700 req/s per replica) against ~1830 req/s per
    // replica of offered load: the backlog breaches and the governor
    // must boost for the equivalence to mean anything.
    let reports = run_all_thread_counts(&cspec, 20);
    let freqs: std::collections::BTreeSet<u64> = reports[0]
        .per_replica
        .iter()
        .flat_map(|p| p.freq_mhz.samples.iter())
        .map(|s| s.value as u64)
        .filter(|&v| v > 0)
        .collect();
    assert!(freqs.len() > 1, "governor must retune (saw {freqs:?})");
}

// ---------------------------------------------------------------------
// Autoscaler under a flash crowd: scale-ups and drain-then-retire
// decisions land on the same barriers regardless of thread count
// (autoscaling forces the narrow path).
// ---------------------------------------------------------------------

#[test]
fn autoscaler_flash_crowd_agrees_across_thread_counts() {
    let spec = ServeSpec::new(
        Arrival::Burst {
            base_rps: 800.0,
            burst_rps: 6000.0,
            period: ms(20),
            duty: 0.4,
        },
        ms(80),
    )
    .policy(DispatchPolicy::JoinShortestQueue)
    .slo(ms(5))
    .sample_interval(ms(2))
    .seed(0x50C);
    let cspec = ClusterSpec::new(4, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .autoscale(AutoscaleSpec::new(1));
    let reports = run_all_thread_counts(&cspec, 50);
    assert!(
        !reports[0].autoscale_actions.is_empty(),
        "the flash crowd must trigger the autoscaler"
    );
}

// ---------------------------------------------------------------------
// Mid-run DFS retune: a scheduled frequency swap hits every replica at
// the same local offset whether replicas step serially or on workers.
// ---------------------------------------------------------------------

#[test]
fn midrun_dfs_retune_agrees_across_thread_counts() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 4000.0 }, ms(60))
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0xD0F5);
    // Start slow, retune the accelerator island up mid-run: completions
    // straddling the swap exercise frequency-dependent service times
    // on both sides of a barrier.
    let cspec = ClusterSpec::new(3, spec)
        .balancer(DispatchPolicy::RoundRobin)
        .schedule_freq(ms(20), 1, 50);
    let reports = run_all_thread_counts(&cspec, 20);
    assert!(reports[0].completed > 100, "retuned fleet still serves");
}

// ---------------------------------------------------------------------
// Property: across seeds, threads {1, 2, all} agree on the merged
// percentiles (and, stronger, on the whole report).
// ---------------------------------------------------------------------

#[test]
fn merged_percentiles_agree_for_every_thread_count() {
    for seed in [1u64, 7, 0xBEEF] {
        let spec = ServeSpec::new(Arrival::Poisson { rps: 4500.0 }, ms(40))
            .slo(ms(5))
            .sample_interval(ms(2))
            .seed(seed);
        let cspec = ClusterSpec::new(3, spec).balancer(DispatchPolicy::RoundRobin);
        let reports = run_all_thread_counts(&cspec, 50);
        let base = &reports[0];
        for r in &reports[1..] {
            assert_eq!(base.latency.p50_ps, r.latency.p50_ps, "seed {seed:#x}: p50");
            assert_eq!(base.latency.p95_ps, r.latency.p95_ps, "seed {seed:#x}: p95");
            assert_eq!(base.latency.p99_ps, r.latency.p99_ps, "seed {seed:#x}: p99");
            assert_eq!(base.slo_attainment, r.slo_attainment, "seed {seed:#x}");
        }
    }
}

// ---------------------------------------------------------------------
// threads = 0 resolves to the machine's cores; absurd explicit counts
// are clamped to the fleet, not an error.
// ---------------------------------------------------------------------

#[test]
fn oversized_thread_counts_clamp_to_the_fleet() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 3000.0 }, ms(30)).seed(9);
    let cspec = ClusterSpec::new(2, spec);
    let serial = cspec.clone().threads(1).run(fleet_cfg(50)).unwrap();
    let absurd = cspec.clone().threads(64).run(fleet_cfg(50)).unwrap();
    assert_eq!(serial, absurd, "64 workers on a 2-slot fleet clamps to 2");
}
