//! Integration: the AOT artifacts load on the PJRT CPU client and agree
//! with the independent native-Rust oracle — the end-to-end check of the
//! whole JAX -> Pallas -> HLO-text -> xla-crate pipeline.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use vespa::mem::Block;
use vespa::runtime::{AccelCompute, DType, Manifest, PjrtCompute, RefCompute};
use vespa::util::SplitMix64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn random_inputs(spec: &vespa::runtime::ModuleSpec, seed: u64) -> Vec<Block> {
    let mut rng = SplitMix64::new(seed);
    spec.inputs
        .iter()
        .map(|ts| match ts.dtype {
            DType::F32 => {
                Block::F32((0..ts.elems()).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            }
            DType::S32 => Block::I32(
                (0..ts.elems())
                    .map(|_| rng.range_i64(-32768, 32767) as i32)
                    .collect(),
            ),
        })
        .collect()
}

#[test]
fn manifest_covers_all_five_accelerators() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let m = Manifest::load(&dir).unwrap();
    let names: Vec<&str> = m.modules.keys().map(String::as_str).collect();
    assert_eq!(names, vec!["adpcm", "dfadd", "dfmul", "dfsin", "gsm"]);
}

#[test]
fn pjrt_matches_native_oracle_on_random_inputs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let mut pjrt = PjrtCompute::from_manifest(manifest.clone()).unwrap();
    let mut refc = RefCompute::new();

    for (round, seed) in [(0u64, 11u64), (1, 22), (2, 33)] {
        for (name, spec) in &manifest.modules {
            let inputs = random_inputs(spec, seed ^ round);
            let refs: Vec<&Block> = inputs.iter().collect();
            let got = pjrt.invoke(name, &refs).unwrap();
            let want = refc.invoke(name, &refs).unwrap();
            assert_eq!(got.len(), want.len(), "{name}: output arity");
            for (o, (g, w)) in got.iter().zip(&want).enumerate() {
                match (g, w) {
                    (Block::F32(a), Block::F32(b)) => {
                        let mut max_err = 0f32;
                        let mut max_mag = 0f32;
                        for (x, y) in a.iter().zip(b) {
                            max_err = max_err.max((x - y).abs());
                            max_mag = max_mag.max(y.abs());
                        }
                        // dfsin's Taylor vs libm and gsm's f32 MAC order
                        // differ in low-order bits only.
                        assert!(
                            max_err <= 1e-3 * max_mag.max(1.0),
                            "{name} output {o}: max err {max_err}"
                        );
                    }
                    (Block::I32(a), Block::I32(b)) => {
                        assert_eq!(a, b, "{name} output {o}: integer mismatch");
                    }
                    _ => panic!("{name} output {o}: dtype mismatch"),
                }
            }
        }
    }
}

#[test]
fn pjrt_output_shapes_match_manifest() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let mut pjrt = PjrtCompute::from_manifest(manifest.clone()).unwrap();
    for (name, spec) in &manifest.modules {
        let inputs = random_inputs(spec, 5);
        let refs: Vec<&Block> = inputs.iter().collect();
        let got = pjrt.invoke(name, &refs).unwrap();
        for (o, ts) in got.iter().zip(&spec.outputs) {
            assert_eq!(o.words(), ts.elems(), "{name}: words");
        }
    }
}

/// Full-system composition: simulate the paper SoC with the PJRT backend
/// on the hot path and validate the accelerator's functional output.
#[test]
fn soc_runs_with_pjrt_backend_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    use vespa::config::presets::{paper_soc, A1_POS};
    use vespa::sim::{stage_inputs_for, Soc};

    let pjrt = PjrtCompute::load(&dir).unwrap();
    let cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
    let mut soc = Soc::build(cfg, Box::new(pjrt)).unwrap();
    let a1 = soc.cfg.node_of(A1_POS.0, A1_POS.1);
    let ids = stage_inputs_for(&mut soc, a1, 1).unwrap();
    soc.run_for(2_000_000_000); // 2 ms: several dfmul invocations

    let inv = soc.mra(a1).invocations();
    assert!(inv >= 2, "invocations {inv}");
    assert!(soc.mra(a1).functional_calls >= 1);

    let a = soc.blocks.get(ids[0][0]).as_f32().unwrap().to_vec();
    let b = soc.blocks.get(ids[0][1]).as_f32().unwrap().to_vec();
    let out = soc.mra(a1).last_outputs[0].as_f32().unwrap();
    for i in 0..a.len() {
        assert!((out[i] - a[i] * b[i]).abs() < 1e-5, "element {i}");
    }
}
