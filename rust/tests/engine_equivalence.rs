//! Engine equivalence suite: the idle-aware engine must be bit-identical
//! to the `reference` tick-everything engine on every observable —
//! simulation time, delivered edges, island cycle counts, frequencies,
//! all monitor counters, router statistics, sampler rows, and typed
//! `PhaseReport`s — across the paper SoC, an all-idle SoC, and a
//! mid-run DFS retune, plus a property sweep showing coalescing never
//! jumps past a host schedule entry or a sampler deadline.

use vespa::config::presets::{paper_soc, A1_POS, ISL_TG};
use vespa::config::SocConfig;
use vespa::runtime::RefCompute;
use vespa::scenario::{ms, PhaseReport, Scenario, Session};
use vespa::sim::{EngineMode, Soc};
use vespa::tiles::Tile;
use vespa::util::proptest::forall;

/// Everything the engines must agree on, bit for bit.
#[derive(Debug, PartialEq)]
struct Snapshot {
    now: u64,
    edges: u64,
    cycles: Vec<u64>,
    freq_mhz: Vec<u64>,
    /// Per tile: invocations, pkts in/out, rtt sum/count, exec cycles.
    counters: Vec<(u64, u64, u64, u64, u64, u64)>,
    mem_pkts_in: u64,
    mem_beats_in: u64,
    /// Summed router stats: flits, packets, stall cycles.
    router_stats: (u64, u64, u64),
    arena_live: usize,
    tg_completed: u64,
    /// Sampler rows, exactly (same deadlines, same edges, same values).
    sampler: Option<Vec<(String, Vec<(u64, f64)>)>>,
}

fn snapshot(soc: &Soc) -> Snapshot {
    Snapshot {
        now: soc.now,
        edges: soc.edges,
        cycles: soc.islands.iter().map(|d| d.cycles).collect(),
        freq_mhz: soc
            .islands
            .iter()
            .map(|d| d.freq(soc.now).as_mhz())
            .collect(),
        counters: soc
            .mon
            .tiles
            .iter()
            .map(|c| {
                (
                    c.invocations,
                    c.pkts_in,
                    c.pkts_out,
                    c.rtt_sum,
                    c.rtt_count,
                    c.exec_cycles,
                )
            })
            .collect(),
        mem_pkts_in: soc.mon.mem_pkts_in,
        mem_beats_in: soc.mon.mem_beats_in,
        router_stats: soc.fabric.routers.iter().fold((0, 0, 0), |a, r| {
            (
                a.0 + r.stats.flits,
                a.1 + r.stats.packets,
                a.2 + r.stats.stall_cycles,
            )
        }),
        arena_live: soc.arena.live(),
        tg_completed: soc
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Tg(tg) => tg.completed,
                _ => 0,
            })
            .sum(),
        sampler: soc.sampler.as_ref().map(|s| {
            s.series
                .iter()
                .map(|ts| {
                    (
                        ts.name.clone(),
                        ts.samples.iter().map(|p| (p.t, p.value)).collect(),
                    )
                })
                .collect()
        }),
    }
}

// ---------------------------------------------------------------------
// (a) The paper SoC under a Session workload.
// ---------------------------------------------------------------------

fn run_paper_session(mode: EngineMode) -> (Snapshot, PhaseReport) {
    let cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
    let mut s = Session::new(cfg).unwrap();
    s.engine(mode);
    let a1 = s.tile_at(A1_POS.0, A1_POS.1);
    s.stage(a1, 1)
        .unwrap()
        .perf_only()
        .with_tg_load(4)
        .warmup(ms(2));
    let report = s.measure(a1, ms(3)).unwrap();
    let soc = s.into_soc();
    (snapshot(&soc), report)
}

#[test]
fn paper_soc_session_is_bit_identical() {
    let (snap_idle, rep_idle) = run_paper_session(EngineMode::IdleAware);
    let (snap_ref, rep_ref) = run_paper_session(EngineMode::Reference);
    assert_eq!(snap_idle, snap_ref);
    assert_eq!(rep_idle, rep_ref, "PhaseReports must match exactly");
    assert!(rep_idle.invocations > 0, "workload actually ran");
}

// ---------------------------------------------------------------------
// (b) An all-idle SoC — the coalescing-dominated extreme.
// ---------------------------------------------------------------------

fn quiet_cfg() -> SocConfig {
    Scenario::grid(3, 2)
        .name("equivalence-quiet")
        .seed(0xE0)
        .island_dfs("noc", 100, 10..=100, 5)
        .island_dfs("tg", 50, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .cpu_at_on(1, 0, "tg")
        .io_at_on(2, 0, "tg")
        .fill_tg("tg")
        .build()
        .unwrap()
}

fn build_quiet(mode: EngineMode, tgs: usize, gap: u32) -> Soc {
    let mut soc = Soc::build(quiet_cfg(), Box::new(RefCompute::new())).unwrap();
    soc.engine = mode;
    for t in &mut soc.tiles {
        if let Tile::Tg(tg) = t {
            tg.gap_cycles = gap;
        }
    }
    soc.host_set_tg_active(tgs);
    soc
}

#[test]
fn all_idle_soc_is_bit_identical_and_coalesces() {
    let mut idle = build_quiet(EngineMode::IdleAware, 0, 0);
    let mut reference = build_quiet(EngineMode::Reference, 0, 0);
    idle.run_until(50_000_000_000); // 50 ms
    reference.run_until(50_000_000_000);
    assert_eq!(snapshot(&idle), snapshot(&reference));
    assert!(
        idle.engine_stats.coalesced_edges as f64 > idle.edges as f64 * 0.99,
        "an idle SoC should be almost entirely coalesced: {:?}",
        idle.engine_stats
    );
    assert_eq!(reference.engine_stats.coalesced_edges, 0);
}

#[test]
fn sparse_bursty_tgs_are_bit_identical() {
    let mut idle = build_quiet(EngineMode::IdleAware, 3, 800);
    let mut reference = build_quiet(EngineMode::Reference, 3, 800);
    idle.run_until(20_000_000_000); // 20 ms
    reference.run_until(20_000_000_000);
    assert_eq!(snapshot(&idle), snapshot(&reference));
    let snap = snapshot(&idle);
    assert!(snap.mem_pkts_in > 0, "bursts actually flowed");
    assert!(
        idle.engine_stats.coalesced_edges > 0 && idle.engine_stats.skipped_tile_ticks > 0,
        "{:?}",
        idle.engine_stats
    );
}

// ---------------------------------------------------------------------
// (c) Mid-run DFS retunes via the host schedule, with the sampler on.
// ---------------------------------------------------------------------

fn run_retune(mode: EngineMode) -> Snapshot {
    // adpcm is compute-bound: long compute stretches exercise the MRA
    // sleep-until-completion path and its exec-cycle bulk credit.
    let cfg = paper_soc(("adpcm", 2), ("dfmul", 1));
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    soc.engine = mode;
    soc.enable_sampler(100_000_000); // 100 us
    soc.host_set_tg_active(6);
    soc.schedule_freq(3_000_000_000, ISL_TG, 20);
    soc.schedule_freq(6_000_000_000, 0, 10); // NoC+MEM island to 10 MHz
    soc.schedule_freq(9_000_000_000, 0, 100);
    soc.run_until(12_000_000_000); // 12 ms
    snapshot(&soc)
}

#[test]
fn dfs_retune_with_sampler_is_bit_identical() {
    let idle = run_retune(EngineMode::IdleAware);
    let reference = run_retune(EngineMode::Reference);
    assert_eq!(idle, reference);
    // The retunes really happened and the sampler really sampled.
    assert_eq!(idle.freq_mhz[0], 100);
    assert_eq!(idle.freq_mhz[ISL_TG], 20);
    let rows = idle.sampler.as_ref().unwrap();
    assert!(rows[0].1.len() > 100, "sampler rows: {}", rows[0].1.len());
}

// ---------------------------------------------------------------------
// Property: coalescing never jumps past a schedule entry or a sampler
// deadline, under randomized sparse workloads.
// ---------------------------------------------------------------------

#[test]
fn prop_coalescing_respects_schedule_and_sampler() {
    forall(
        0xC0A1E5CE,
        10,
        |r| {
            let interval = (r.next_below(20) + 1) * 10_000_000; // 10..200 us
            let sched_t = (r.next_below(40) + 1) * 100_000_000; // 0.1..4 ms
            let mhz = 10 + 5 * r.next_below(9); // 10..50 on the 5 MHz grid
            let gap = r.next_below(3000) as u32;
            let tgs = 1 + r.next_below(3) as usize;
            (interval, sched_t, mhz, gap, tgs)
        },
        |&(interval, sched_t, mhz, gap, tgs)| {
            let run = |mode: EngineMode| {
                let mut soc = build_quiet(mode, tgs, gap);
                soc.enable_sampler(interval);
                soc.schedule_freq(sched_t, 1, mhz); // island 1 = "tg" (DFS)
                soc.run_until(5_000_000_000); // 5 ms
                snapshot(&soc)
            };
            let idle = run(EngineMode::IdleAware);
            let reference = run(EngineMode::Reference);
            assert_eq!(idle, reference);
            // The sample cadence is exact: rows at every deadline edge.
            let rows = &idle.sampler.as_ref().unwrap()[0].1;
            assert!(rows.len() as u64 >= 5_000_000_000 / interval / 2);
        },
    );
}
