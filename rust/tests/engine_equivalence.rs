//! Engine equivalence suite: the idle-aware and event-driven engines
//! must be bit-identical to the `reference` tick-everything engine on
//! every observable — simulation time, delivered edges, island cycle
//! counts, frequencies, all monitor counters, router statistics,
//! sampler rows, and typed `PhaseReport`s / `ServeReport`s /
//! `ClusterReport`s — across the paper SoC, an all-idle SoC, mid-run
//! DFS retunes, the serving and cluster paths, plus a property sweep
//! showing coalescing never jumps past a host schedule entry or a
//! sampler deadline.

use vespa::cluster::{ClusterReport, ClusterSpec};
use vespa::config::presets::{paper_soc, A1_POS, A2_POS, ISL_A1, ISL_TG};
use vespa::config::SocConfig;
use vespa::runtime::RefCompute;
use vespa::scenario::{ms, PhaseReport, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, GovernorSpec, ServeReport, ServeSpec};
use vespa::sim::{EngineMode, Soc};
use vespa::tiles::Tile;
use vespa::util::proptest::forall;

/// Everything the engines must agree on, bit for bit.
#[derive(Debug, PartialEq)]
struct Snapshot {
    now: u64,
    edges: u64,
    cycles: Vec<u64>,
    freq_mhz: Vec<u64>,
    /// Per tile: invocations, pkts in/out, rtt sum/count, exec cycles.
    counters: Vec<(u64, u64, u64, u64, u64, u64)>,
    mem_pkts_in: u64,
    mem_beats_in: u64,
    /// Summed router stats: flits, packets, stall cycles.
    router_stats: (u64, u64, u64),
    arena_live: usize,
    tg_completed: u64,
    /// Sampler rows, exactly (same deadlines, same edges, same values).
    sampler: Option<Vec<(String, Vec<(u64, f64)>)>>,
}

fn snapshot(soc: &Soc) -> Snapshot {
    Snapshot {
        now: soc.now,
        edges: soc.edges,
        cycles: soc.islands.iter().map(|d| d.cycles).collect(),
        freq_mhz: soc
            .islands
            .iter()
            .map(|d| d.freq(soc.now).as_mhz())
            .collect(),
        counters: soc
            .mon
            .tiles
            .iter()
            .map(|c| {
                (
                    c.invocations,
                    c.pkts_in,
                    c.pkts_out,
                    c.rtt_sum,
                    c.rtt_count,
                    c.exec_cycles,
                )
            })
            .collect(),
        mem_pkts_in: soc.mon.mem_pkts_in,
        mem_beats_in: soc.mon.mem_beats_in,
        router_stats: soc.fabric.routers.iter().fold((0, 0, 0), |a, r| {
            (
                a.0 + r.stats.flits,
                a.1 + r.stats.packets,
                a.2 + r.stats.stall_cycles,
            )
        }),
        arena_live: soc.arena.live(),
        tg_completed: soc
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Tg(tg) => tg.completed,
                _ => 0,
            })
            .sum(),
        sampler: soc.sampler.as_ref().map(|s| {
            s.series
                .iter()
                .map(|ts| {
                    (
                        ts.name.clone(),
                        ts.samples.iter().map(|p| (p.t, p.value)).collect(),
                    )
                })
                .collect()
        }),
    }
}

// ---------------------------------------------------------------------
// (a) The paper SoC under a Session workload.
// ---------------------------------------------------------------------

fn run_paper_session(mode: EngineMode) -> (Snapshot, PhaseReport) {
    let cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
    let mut s = Session::new(cfg).unwrap();
    s.engine(mode);
    let a1 = s.tile_at(A1_POS.0, A1_POS.1);
    s.stage(a1, 1)
        .unwrap()
        .perf_only()
        .with_tg_load(4)
        .warmup(ms(2));
    let report = s.measure(a1, ms(3)).unwrap();
    let soc = s.into_soc();
    (snapshot(&soc), report)
}

#[test]
fn paper_soc_session_is_bit_identical() {
    let (snap_idle, rep_idle) = run_paper_session(EngineMode::IdleAware);
    let (snap_ref, rep_ref) = run_paper_session(EngineMode::Reference);
    let (snap_event, rep_event) = run_paper_session(EngineMode::EventDriven);
    assert_eq!(snap_idle, snap_ref);
    assert_eq!(snap_event, snap_ref, "event engine drifted from reference");
    assert_eq!(rep_idle, rep_ref, "PhaseReports must match exactly");
    assert_eq!(rep_event, rep_ref, "PhaseReports must match exactly");
    assert!(rep_idle.invocations > 0, "workload actually ran");
}

// ---------------------------------------------------------------------
// (b) An all-idle SoC — the coalescing-dominated extreme.
// ---------------------------------------------------------------------

fn quiet_cfg() -> SocConfig {
    Scenario::grid(3, 2)
        .name("equivalence-quiet")
        .seed(0xE0)
        .island_dfs("noc", 100, 10..=100, 5)
        .island_dfs("tg", 50, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .cpu_at_on(1, 0, "tg")
        .io_at_on(2, 0, "tg")
        .fill_tg("tg")
        .build()
        .unwrap()
}

fn build_quiet(mode: EngineMode, tgs: usize, gap: u32) -> Soc {
    let mut soc = Soc::build(quiet_cfg(), Box::new(RefCompute::new())).unwrap();
    soc.engine = mode;
    for t in &mut soc.tiles {
        if let Tile::Tg(tg) = t {
            tg.gap_cycles = gap;
        }
    }
    soc.host_set_tg_active(tgs);
    soc
}

#[test]
fn all_idle_soc_is_bit_identical_and_coalesces() {
    let mut idle = build_quiet(EngineMode::IdleAware, 0, 0);
    let mut event = build_quiet(EngineMode::EventDriven, 0, 0);
    let mut reference = build_quiet(EngineMode::Reference, 0, 0);
    idle.run_until(50_000_000_000); // 50 ms
    event.run_until(50_000_000_000);
    reference.run_until(50_000_000_000);
    assert_eq!(snapshot(&idle), snapshot(&reference));
    assert_eq!(snapshot(&event), snapshot(&reference));
    assert!(
        idle.engine_stats.coalesced_edges as f64 > idle.edges as f64 * 0.99,
        "an idle SoC should be almost entirely coalesced: {:?}",
        idle.engine_stats
    );
    assert!(
        event.engine_stats.coalesced_edges as f64 > event.edges as f64 * 0.99,
        "an idle SoC should be almost entirely coalesced: {:?}",
        event.engine_stats
    );
    assert_eq!(reference.engine_stats.coalesced_edges, 0);
}

#[test]
fn sparse_bursty_tgs_are_bit_identical() {
    let mut idle = build_quiet(EngineMode::IdleAware, 3, 800);
    let mut event = build_quiet(EngineMode::EventDriven, 3, 800);
    let mut reference = build_quiet(EngineMode::Reference, 3, 800);
    idle.run_until(20_000_000_000); // 20 ms
    event.run_until(20_000_000_000);
    reference.run_until(20_000_000_000);
    assert_eq!(snapshot(&idle), snapshot(&reference));
    assert_eq!(snapshot(&event), snapshot(&reference));
    let snap = snapshot(&idle);
    assert!(snap.mem_pkts_in > 0, "bursts actually flowed");
    assert!(
        idle.engine_stats.coalesced_edges > 0 && idle.engine_stats.skipped_tile_ticks > 0,
        "{:?}",
        idle.engine_stats
    );
    assert!(
        event.engine_stats.coalesced_edges > 0,
        "{:?}",
        event.engine_stats
    );
}

// ---------------------------------------------------------------------
// (c) Mid-run DFS retunes via the host schedule, with the sampler on.
// ---------------------------------------------------------------------

fn run_retune(mode: EngineMode) -> Snapshot {
    // adpcm is compute-bound: long compute stretches exercise the MRA
    // sleep-until-completion path and its exec-cycle bulk credit.
    let cfg = paper_soc(("adpcm", 2), ("dfmul", 1));
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    soc.engine = mode;
    soc.enable_sampler(100_000_000); // 100 us
    soc.host_set_tg_active(6);
    soc.schedule_freq(3_000_000_000, ISL_TG, 20);
    soc.schedule_freq(6_000_000_000, 0, 10); // NoC+MEM island to 10 MHz
    soc.schedule_freq(9_000_000_000, 0, 100);
    soc.run_until(12_000_000_000); // 12 ms
    snapshot(&soc)
}

#[test]
fn dfs_retune_with_sampler_is_bit_identical() {
    let idle = run_retune(EngineMode::IdleAware);
    let event = run_retune(EngineMode::EventDriven);
    let reference = run_retune(EngineMode::Reference);
    assert_eq!(idle, reference);
    assert_eq!(event, reference, "event engine drifted across retunes");
    // The retunes really happened and the sampler really sampled.
    assert_eq!(idle.freq_mhz[0], 100);
    assert_eq!(idle.freq_mhz[ISL_TG], 20);
    let rows = idle.sampler.as_ref().unwrap();
    assert!(rows[0].1.len() > 100, "sampler rows: {}", rows[0].1.len());
}

// ---------------------------------------------------------------------
// (d) The serving path: open-loop Poisson traffic with the queue-driven
// DFS governor, judged by the full typed ServeReport.
// ---------------------------------------------------------------------

fn run_serve(mode: EngineMode) -> ServeReport {
    let cfg = paper_soc(("dfmul", 2), ("dfmul", 2));
    let mut s = Session::new(cfg).unwrap();
    s.engine(mode);
    let a1 = s.tile_at(A1_POS.0, A1_POS.1);
    let a2 = s.tile_at(A2_POS.0, A2_POS.1);
    let slo = 5_000_000_000; // 5 ms
    let spec = ServeSpec::new(Arrival::Poisson { rps: 1200.0 }, ms(15))
        .tiles(vec![a1, a2])
        .policy(DispatchPolicy::JoinShortestQueue)
        .queue_capacity(16)
        .slo(slo)
        .seed(0xE5B)
        .governor(GovernorSpec::new(ISL_A1, slo));
    s.serve(&spec).unwrap()
}

#[test]
fn serve_path_is_bit_identical() {
    let idle = run_serve(EngineMode::IdleAware);
    let event = run_serve(EngineMode::EventDriven);
    let reference = run_serve(EngineMode::Reference);
    assert_eq!(idle, reference, "idle-aware ServeReport drifted");
    assert_eq!(event, reference, "event ServeReport drifted");
    assert!(reference.completed > 0, "requests actually served");
}

// ---------------------------------------------------------------------
// (e) The cluster path: a replica fleet behind the front-end balancer,
// judged by the merged typed ClusterReport.
// ---------------------------------------------------------------------

fn run_cluster(mode: EngineMode) -> ClusterReport {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 2500.0 }, ms(10))
        .policy(DispatchPolicy::JoinShortestQueue)
        .queue_capacity(16)
        .slo(5_000_000_000)
        .seed(0x77);
    let cspec = ClusterSpec::new(2, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .engine(mode);
    cspec.run(paper_soc(("dfmul", 2), ("dfmul", 2))).unwrap()
}

#[test]
fn cluster_path_is_bit_identical() {
    let idle = run_cluster(EngineMode::IdleAware);
    let event = run_cluster(EngineMode::EventDriven);
    let reference = run_cluster(EngineMode::Reference);
    assert_eq!(idle, reference, "idle-aware ClusterReport drifted");
    assert_eq!(event, reference, "event ClusterReport drifted");
    assert!(reference.completed > 0, "requests actually served");
}

// ---------------------------------------------------------------------
// Property: coalescing never jumps past a schedule entry or a sampler
// deadline, under randomized sparse workloads.
// ---------------------------------------------------------------------

#[test]
fn prop_coalescing_respects_schedule_and_sampler() {
    forall(
        0xC0A1E5CE,
        10,
        |r| {
            let interval = (r.next_below(20) + 1) * 10_000_000; // 10..200 us
            let sched_t = (r.next_below(40) + 1) * 100_000_000; // 0.1..4 ms
            let mhz = 10 + 5 * r.next_below(9); // 10..50 on the 5 MHz grid
            let gap = r.next_below(3000) as u32;
            let tgs = 1 + r.next_below(3) as usize;
            (interval, sched_t, mhz, gap, tgs)
        },
        |&(interval, sched_t, mhz, gap, tgs)| {
            let run = |mode: EngineMode| {
                let mut soc = build_quiet(mode, tgs, gap);
                soc.enable_sampler(interval);
                soc.schedule_freq(sched_t, 1, mhz); // island 1 = "tg" (DFS)
                soc.run_until(5_000_000_000); // 5 ms
                snapshot(&soc)
            };
            let idle = run(EngineMode::IdleAware);
            let event = run(EngineMode::EventDriven);
            let reference = run(EngineMode::Reference);
            assert_eq!(idle, reference);
            assert_eq!(event, reference, "event engine drifted");
            // The sample cadence is exact: rows at every deadline edge.
            let rows = &idle.sampler.as_ref().unwrap()[0].1;
            assert!(rows.len() as u64 >= 5_000_000_000 / interval / 2);
        },
    );
}
