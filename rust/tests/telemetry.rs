//! System tests for the telemetry subsystem — the ISSUE's acceptance
//! criteria: (a) with the same seed and spec, the exported trace is
//! **byte-identical** across all three [`EngineMode`]s and across
//! `threads {1, 2, 0}` on the cluster path; (b) a request in flight on
//! a crashed replica keeps one span whose rescued completion is timed
//! from the *original* arrival; (c) `verify_accounting()` holds on
//! real serve and cluster reports, trace counters included; (d) the
//! metrics snapshot parses (JSON) and exposes the stable names
//! (Prometheus text).

use vespa::cluster::ClusterSpec;
use vespa::config::SocConfig;
use vespa::fault::{FaultPlan, HealthSpec, RetrySpec};
use vespa::scenario::{ms, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};
use vespa::sim::EngineMode;
use vespa::telemetry::{to_perfetto, MetricsRegistry, SpanEvent, Trace, TraceSpec};
use vespa::util::Ps;

const US: Ps = 1_000_000;

/// One 2-replica dfmul tile on a governable island — the same
/// per-replica SoC as the cluster and fault suites (~4250 req/s at
/// 50 MHz).
fn fleet_cfg() -> SocConfig {
    Scenario::grid(2, 2)
        .name("telemetry-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", 50, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

/// Node index of the accelerator tile (the fault plans' `t<N>` target).
fn accel_tile() -> usize {
    Session::new(fleet_cfg()).unwrap().mra_tiles()[0]
}

const ALL_ENGINES: [EngineMode; 3] = [
    EngineMode::Reference,
    EngineMode::IdleAware,
    EngineMode::EventDriven,
];

// ---------------------------------------------------------------------
// (a) Serve: byte-identical Perfetto export across engine modes, with
//     faults and retries in the mix.
// ---------------------------------------------------------------------

#[test]
fn serve_trace_is_byte_identical_across_engine_modes() {
    let t = accel_tile();
    let plan = FaultPlan::parse(&format!("hang@t{t}:at=10ms,dur=3ms")).unwrap();
    let spec = ServeSpec::new(Arrival::Poisson { rps: 5000.0 }, ms(40))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .seed(0x7AC3)
        .faults(plan)
        .retry(RetrySpec::new(3, 500 * US))
        .trace(TraceSpec::new());

    let exports: Vec<String> = ALL_ENGINES
        .iter()
        .map(|&mode| {
            let mut s = Session::new(fleet_cfg()).unwrap();
            s.engine(mode);
            let report = s.serve(&spec).unwrap();
            report.verify_accounting().unwrap();
            let trace = report.trace.as_ref().expect("tracing was enabled");
            assert!(trace.recorded > 100, "{mode:?}: enough spans recorded");
            assert_eq!(
                trace.total_requests, report.offered,
                "{mode:?}: every request is counted"
            );
            to_perfetto(trace)
        })
        .collect();
    for (i, e) in exports.iter().enumerate().skip(1) {
        assert_eq!(
            &exports[0], e,
            "{:?} trace diverged from {:?}",
            ALL_ENGINES[i], ALL_ENGINES[0]
        );
    }
}

// ---------------------------------------------------------------------
// (a) Cluster: byte-identical export across engines x threads {1,2,0},
//     with a ReplicaCrash + retry in the plan — the hardest ordering
//     case (crash rebinding crosses replica boundaries).
// ---------------------------------------------------------------------

/// A traced cluster spec with a mid-run crash of slot 0 under retry +
/// health checks: interrupted requests are rescued onto the survivor.
fn crashy_cluster() -> ClusterSpec {
    let t = accel_tile();
    let plan =
        FaultPlan::parse(&format!("hang@t{t}@r0:at=16ms,dur=3ms;crash@r0:at=20ms")).unwrap();
    let spec = ServeSpec::new(Arrival::Poisson { rps: 6000.0 }, ms(60))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0x5AFE)
        .faults(plan)
        .retry(RetrySpec::new(4, 500 * US));
    ClusterSpec::new(2, spec)
        .balancer(DispatchPolicy::RoundRobin)
        .health(HealthSpec::new())
        .trace(TraceSpec::new().capacity(100_000))
}

#[test]
fn cluster_trace_is_byte_identical_across_engines_and_threads() {
    let cspec = crashy_cluster();
    let mut exports: Vec<(String, String)> = Vec::new();
    for mode in ALL_ENGINES {
        for threads in [1usize, 2, 0] {
            let report = cspec
                .clone()
                .engine(mode)
                .threads(threads)
                .run(fleet_cfg())
                .unwrap_or_else(|e| panic!("{mode:?} threads={threads}: {e}"));
            report.verify_accounting().unwrap();
            assert!(report.faults.rescued > 0, "{mode:?}: crash rescued work");
            let trace = report.trace.as_ref().expect("tracing was enabled");
            assert!(trace.recorded > 100, "{mode:?}: enough spans recorded");
            exports.push((format!("{mode:?}/threads={threads}"), to_perfetto(trace)));
        }
    }
    let (base_name, base) = &exports[0];
    for (name, e) in &exports[1..] {
        assert_eq!(base, e, "{name} trace diverged from {base_name}");
    }
}

// ---------------------------------------------------------------------
// (b) The rescued span: crash -> retry -> readmit -> complete, all in
//     ONE span whose latency covers the original arrival.
// ---------------------------------------------------------------------

#[test]
fn crashed_request_keeps_one_span_covering_original_arrival() {
    let report = crashy_cluster().run(fleet_cfg()).unwrap();
    let trace = report.trace.as_ref().unwrap();
    let crashed: Vec<_> = trace
        .spans
        .iter()
        .filter(|s| {
            s.events
                .iter()
                .any(|(_, e)| matches!(e, SpanEvent::Crashed { .. }))
        })
        .collect();
    assert!(!crashed.is_empty(), "the crash caught requests in flight");

    let rescued: Vec<_> = crashed
        .iter()
        .filter(|s| s.latency.is_some())
        .copied()
        .collect();
    assert!(!rescued.is_empty(), "some crashed spans completed via retry");
    for s in &rescued {
        // The span is one life: admitted, crashed, parked for retry,
        // readmitted (attempt > 0), and completed — in that order.
        let t_crash = s
            .events
            .iter()
            .find(|(_, e)| matches!(e, SpanEvent::Crashed { .. }))
            .map(|&(t, _)| t)
            .unwrap();
        assert!(
            s.events.iter().any(|(t, e)| {
                *t >= t_crash && matches!(e, SpanEvent::Admit { attempt, .. } if *attempt > 0)
            }),
            "span {} readmitted after the crash: {:?}",
            s.id,
            s.events
        );
        let (t_done, lat) = s
            .events
            .iter()
            .find_map(|&(t, e)| match e {
                SpanEvent::Complete { latency, .. } => Some((t, latency)),
                _ => None,
            })
            .unwrap();
        assert_eq!(
            lat,
            t_done - s.t_arr,
            "span {}: rescued latency is timed from the original arrival",
            s.id
        );
        assert!(t_done > t_crash, "completion follows the crash");
    }
}

// ---------------------------------------------------------------------
// Sampling: 1-in-N records ~total/N spans and never perturbs the
// simulation itself (the report minus the trace is unchanged).
// ---------------------------------------------------------------------

#[test]
fn sampling_thins_the_trace_without_perturbing_the_run() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 4000.0 }, ms(40))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .seed(0x5A3D);
    let run = |ts: Option<TraceSpec>| {
        let mut s = Session::new(fleet_cfg()).unwrap();
        let spec = match ts {
            Some(ts) => spec.clone().trace(ts),
            None => spec.clone(),
        };
        s.serve(&spec).unwrap()
    };
    let untraced = run(None);
    let full = run(Some(TraceSpec::new()));
    let sampled = run(Some(TraceSpec::new().sample(10)));

    let t_full = full.trace.as_ref().unwrap();
    let t_thin = sampled.trace.as_ref().unwrap();
    assert_eq!(t_full.recorded, t_full.total_requests);
    assert_eq!(t_thin.total_requests, t_full.total_requests);
    assert_eq!(t_thin.recorded, t_full.total_requests.div_ceil(10));

    // Tracing observes; it must not steer. Strip the trace and the
    // reports are bit-identical to the untraced run.
    let strip = |mut r: vespa::serve::ServeReport| {
        r.trace = None;
        r
    };
    assert_eq!(strip(full), untraced, "full tracing perturbed the run");
    assert_eq!(strip(sampled), untraced, "sampling perturbed the run");
}

// ---------------------------------------------------------------------
// (d) Metrics: the JSON snapshot parses with the repo's own reader and
//     matches the report; the Prometheus text carries the stable names.
// ---------------------------------------------------------------------

#[test]
fn metrics_snapshot_parses_and_matches_the_report() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 5000.0 }, ms(30))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .seed(0x3E7)
        .trace(TraceSpec::new());
    let mut session = Session::new(fleet_cfg()).unwrap();
    let report = session.serve(&spec).unwrap();
    let mut reg = MetricsRegistry::from_serve(&report);
    reg.add_soc(session.soc());

    let json = vespa::bench_harness::json::parse(&reg.to_json()).unwrap();
    let metrics = json.get("metrics").and_then(|m| m.as_array()).unwrap();
    assert!(!metrics.is_empty());
    let find = |name: &str| {
        metrics
            .iter()
            .find(|m| m.get("name").and_then(|n| n.as_str()) == Some(name))
            .unwrap_or_else(|| panic!("{name} missing from the JSON snapshot"))
    };
    assert_eq!(
        find("vespa_requests_completed_total")
            .get("value")
            .and_then(|v| v.as_f64()),
        Some(report.completed as f64)
    );
    assert_eq!(
        find("vespa_trace_requests_total")
            .get("value")
            .and_then(|v| v.as_f64()),
        Some(report.offered as f64)
    );

    let text = reg.to_prometheus();
    for name in [
        "vespa_requests_offered_total",
        "vespa_requests_completed_total",
        "vespa_latency_ms",
        "vespa_tile_queue_depth_max",
        "vespa_engine_tile_ticks_total",
        "vespa_trace_recorded_total",
    ] {
        assert!(text.contains(name), "{name} missing from Prometheus text");
    }
    assert!(
        text.contains("# TYPE vespa_requests_offered_total counter"),
        "_total names are typed as counters"
    );
}

// ---------------------------------------------------------------------
// The CLI's export path end to end: a traced cluster run renders a
// waterfall and a valid Perfetto document.
// ---------------------------------------------------------------------

#[test]
fn perfetto_export_and_waterfall_render_from_a_real_run() {
    let report = crashy_cluster().run(fleet_cfg()).unwrap();
    let trace: &Trace = report.trace.as_ref().unwrap();

    let doc = vespa::bench_harness::json::parse(&to_perfetto(trace)).unwrap();
    let events = doc.get("traceEvents").and_then(|e| e.as_array()).unwrap();
    assert!(events.len() > 100, "one event per span transition");

    let chart = vespa::report::waterfall(trace, 70, 0);
    assert!(chart.contains("span waterfall"), "{chart}");
    assert!(chart.contains("ms"), "{chart}");

    let metrics = MetricsRegistry::from_cluster(&report);
    assert!(metrics
        .to_prometheus()
        .contains("vespa_cluster_fleet_size"));
}
