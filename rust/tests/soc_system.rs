//! System-level integration tests over the full simulator (native
//! backend): monitoring semantics, DFS behaviour under load, MRA
//! scaling, MMIO-over-NoC, and determinism.

use vespa::config::presets::{paper_soc, A1_POS, A2_POS, ISL_A1, ISL_NOC};
use vespa::monitor::CounterReg;
use vespa::policy::{run_with_policy, StaticSchedule};
use vespa::runtime::RefCompute;
use vespa::sim::{stage_inputs_for, Soc, ThroughputProbe};
use vespa::tiles::Tile;

fn build(a1: (&str, usize), a2: (&str, usize)) -> Soc {
    Soc::build(paper_soc(a1, a2), Box::new(RefCompute::new())).unwrap()
}

fn setup_mra(soc: &mut Soc, pos: (u16, u16)) -> usize {
    let t = soc.cfg.node_of(pos.0, pos.1);
    stage_inputs_for(soc, t, 1).unwrap();
    soc.mra_mut(t).functional_every_invocation = false;
    t
}

#[test]
fn monitoring_counters_populate_during_run() {
    let mut soc = build(("dfmul", 2), ("gsm", 1));
    let a1 = setup_mra(&mut soc, A1_POS);
    soc.run_for(3_000_000_000);
    assert!(soc.host_read_counter(a1, CounterReg::Invocations) > 0);
    assert!(soc.host_read_counter(a1, CounterReg::PktsIn) > 0);
    assert!(soc.host_read_counter(a1, CounterReg::PktsOut) > 0);
    assert!(soc.host_read_counter(a1, CounterReg::RttCnt) > 0);
    assert!(soc.host_read_counter(a1, CounterReg::ExecTime) > 0);
    let rtt = soc.mon.tile(a1).rtt_mean();
    assert!(rtt > 100.0 && rtt < 100_000_000.0, "rtt {rtt} ps");
}

#[test]
fn manual_reset_clears_counters_via_mmio_path() {
    let mut soc = build(("dfmul", 1), ("dfadd", 1));
    let a1 = setup_mra(&mut soc, A1_POS);
    soc.run_for(2_000_000_000);
    assert!(soc.host_read_counter(a1, CounterReg::PktsOut) > 0);
    soc.mon.tile_mut(a1).manual_reset();
    assert_eq!(soc.host_read_counter(a1, CounterReg::PktsOut), 0);
    assert_eq!(soc.host_read_counter(a1, CounterReg::Invocations), 0);
}

#[test]
fn cpu_polls_counters_over_config_plane() {
    let mut cfg = paper_soc(("dfmul", 1), ("dfadd", 1));
    cfg.cpu_poll_interval = 50;
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    setup_mra(&mut soc, A1_POS);
    soc.run_for(2_000_000_000);
    let polls = soc
        .tiles
        .iter()
        .find_map(|t| match t {
            Tile::Cpu(c) => Some(c.polls_completed),
            _ => None,
        })
        .unwrap();
    assert!(polls > 10, "CPU completed {polls} MMIO polls over the NoC");
}

#[test]
fn dfs_slowdown_reduces_accel_throughput() {
    let mut soc = build(("dfmul", 2), ("dfadd", 1));
    let a1 = setup_mra(&mut soc, A1_POS);
    soc.run_for(2_000_000_000);
    let p50 = ThroughputProbe::begin(&soc, a1);
    soc.run_for(4_000_000_000);
    let thr50 = p50.mbs(&soc);

    soc.host_write_freq(ISL_A1, 10).unwrap();
    soc.run_for(100_000_000); // actuator swap + settle
    let p10 = ThroughputProbe::begin(&soc, a1);
    soc.run_for(4_000_000_000);
    let thr10 = p10.mbs(&soc);

    let ratio = thr10 / thr50;
    assert!(
        (0.12..=0.40).contains(&ratio),
        "50->10 MHz should cut throughput ~5x: {thr50:.2} -> {thr10:.2}"
    );
}

#[test]
fn noc_frequency_affects_memory_bound_accel_only() {
    // dfmul in A2 at NoC 100 vs 10 MHz: big hit. dfsin (compute-bound):
    // negligible. This is the Fig. 3 mechanism as an integration test.
    let measure = |accel: &str, noc_mhz: u64, window: u64| -> f64 {
        let mut cfg = paper_soc(("dfadd", 1), (accel, 4));
        cfg.islands[ISL_NOC].freq_mhz = noc_mhz;
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        let a2 = setup_mra(&mut soc, A2_POS);
        soc.run_for(window / 2);
        let p = ThroughputProbe::begin(&soc, a2);
        soc.run_for(window);
        p.mbs(&soc)
    };
    let dfmul_fast = measure("dfmul", 100, 4_000_000_000);
    let dfmul_slow = measure("dfmul", 10, 4_000_000_000);
    assert!(
        dfmul_slow < dfmul_fast * 0.75,
        "dfmul: {dfmul_fast:.2} -> {dfmul_slow:.2}"
    );
    let dfsin_fast = measure("dfsin", 100, 30_000_000_000);
    let dfsin_slow = measure("dfsin", 10, 30_000_000_000);
    assert!(
        dfsin_slow > dfsin_fast * 0.9,
        "dfsin: {dfsin_fast:.3} -> {dfsin_slow:.3}"
    );
}

#[test]
fn runs_are_bit_deterministic() {
    let run = || -> (u64, u64, u64) {
        let mut soc = build(("gsm", 2), ("adpcm", 1));
        let a1 = setup_mra(&mut soc, A1_POS);
        soc.host_set_tg_active(5);
        soc.run_for(5_000_000_000);
        (
            soc.mon.mem_pkts_in,
            soc.host_read_counter(a1, CounterReg::PktsOut),
            soc.fabric.total_flits(),
        )
    };
    assert_eq!(run(), run(), "same seed, same everything");
}

#[test]
fn seed_changes_tg_traffic_pattern_not_results_shape() {
    let run = |seed: u64| -> u64 {
        let mut cfg = paper_soc(("dfadd", 1), ("dfadd", 1));
        cfg.seed = seed;
        let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
        soc.host_set_tg_active(8);
        soc.run_for(3_000_000_000);
        soc.mon.mem_pkts_in
    };
    let a = run(1);
    let b = run(2);
    // Different seeds shift addresses but the traffic volume is similar.
    let ratio = a as f64 / b as f64;
    assert!((0.9..=1.1).contains(&ratio), "{a} vs {b}");
}

#[test]
fn static_schedule_fig4_style_run_with_sampler() {
    let mut soc = build(("dfmul", 4), ("dfmul", 4));
    setup_mra(&mut soc, A1_POS);
    setup_mra(&mut soc, A2_POS);
    soc.host_set_tg_active(11);
    soc.enable_sampler(1_000_000_000);
    let mut sched = StaticSchedule::new(vec![
        (5_000_000_000, ISL_NOC, 20),
        (20_000_000_000, ISL_NOC, 100),
    ]);
    run_with_policy(&mut soc, &mut sched, 1_000_000_000, 40_000_000_000).unwrap();
    assert_eq!(sched.pending(), 0);
    let s = soc.sampler.as_ref().unwrap();
    let rate = s.series("mem_pkts_in").unwrap().to_rate();
    // Traffic in the 100 MHz phase beats the 20 MHz phase.
    let slow = rate.mean_in(10_000_000_000, 20_000_000_000);
    let fast = rate.mean_in(32_000_000_000, 40_000_000_000);
    assert!(fast > slow * 2.0, "slow {slow:.0} fast {fast:.0}");
}

#[test]
fn wide_soc_configs_build_and_run() {
    // Beyond the paper's 4x4: an 8x4 grid exercises topology generality.
    let mut cfg = paper_soc(("dfmul", 2), ("gsm", 1));
    // Rebuild as 8x4: duplicate the tile column pattern.
    cfg.width = 8;
    let mut tiles = cfg.tiles.clone();
    for t in &mut tiles {
        t.x += 4; // shift the original grid right
    }
    // Fill the left half with TGs.
    let mut left: Vec<vespa::config::TileSpec> = Vec::new();
    for y in 0..4u16 {
        for x in 0..4u16 {
            left.push(vespa::config::TileSpec {
                x,
                y,
                kind: vespa::config::TileKind::Tg,
                island: 3,
            });
        }
    }
    left.extend(tiles);
    cfg.tiles = left;
    cfg.name = "8x4".into();
    let mut soc = Soc::build(cfg, Box::new(RefCompute::new())).unwrap();
    soc.host_set_tg_active(4);
    soc.run_for(1_000_000_000);
    assert!(soc.mon.mem_pkts_in > 0);
}
