//! Snapshot/fork equivalence suite.
//!
//! `Session::snapshot()` + `Session::resume()` with unchanged
//! frequencies must be **bit-identical** to continuing the original
//! simulation — same counters, sampler traces, router stats, arena
//! occupancy, and typed `PhaseReport`s — on the paper SoC, including a
//! snapshot taken while a DFS retune is still in flight. On top of that
//! contract, the warm-fork sweep planner (`SweepMode::WarmFork`) must
//! return throughputs within a stated tolerance of the cold reference
//! path across a frequency sweep (warm points measure after a run-time
//! retune rather than a cold per-point warmup, so they are
//! tolerance-gated, not bit-exact — see docs/PERF.md).

use vespa::config::presets::{paper_soc, A1_POS, ISL_A1};
use vespa::dse::{clear_memo, memo_len, sweep_replication, SweepMode, SweepParams};
use vespa::scenario::{ms, PhaseReport, Session, SocSnapshot};
use vespa::sim::Soc;
use vespa::tiles::Tile;

/// Everything a fork must agree on with its origin, bit for bit.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    now: u64,
    edges: u64,
    cycles: Vec<u64>,
    freq_mhz: Vec<u64>,
    /// Per tile: invocations, pkts in/out, rtt sum/count, exec cycles.
    counters: Vec<(u64, u64, u64, u64, u64, u64)>,
    mem_pkts_in: u64,
    mem_beats_in: u64,
    /// Summed router stats: flits, packets, stall cycles.
    router_stats: (u64, u64, u64),
    arena_live: usize,
    arena_allocated: u64,
    tg_completed: u64,
    /// Sampler rows, exactly (same deadlines, same values).
    sampler: Option<Vec<(String, Vec<(u64, f64)>)>>,
}

fn fingerprint(soc: &Soc) -> Fingerprint {
    Fingerprint {
        now: soc.now,
        edges: soc.edges,
        cycles: soc.islands.iter().map(|d| d.cycles).collect(),
        freq_mhz: soc
            .islands
            .iter()
            .map(|d| d.freq(soc.now).as_mhz())
            .collect(),
        counters: soc
            .mon
            .tiles
            .iter()
            .map(|c| {
                (
                    c.invocations,
                    c.pkts_in,
                    c.pkts_out,
                    c.rtt_sum,
                    c.rtt_count,
                    c.exec_cycles,
                )
            })
            .collect(),
        mem_pkts_in: soc.mon.mem_pkts_in,
        mem_beats_in: soc.mon.mem_beats_in,
        router_stats: soc.fabric.routers.iter().fold((0, 0, 0), |a, r| {
            (
                a.0 + r.stats.flits,
                a.1 + r.stats.packets,
                a.2 + r.stats.stall_cycles,
            )
        }),
        arena_live: soc.arena.live(),
        arena_allocated: soc.arena.allocated(),
        tg_completed: soc
            .tiles
            .iter()
            .map(|t| match t {
                Tile::Tg(tg) => tg.completed,
                _ => 0,
            })
            .sum(),
        sampler: soc.sampler.as_ref().map(|s| {
            s.series
                .iter()
                .map(|ts| {
                    (
                        ts.name.clone(),
                        ts.samples.iter().map(|p| (p.t, p.value)).collect(),
                    )
                })
                .collect()
        }),
    }
}

/// A warmed paper-SoC session with traffic, sampling, and a staged
/// accelerator — the state a warm-start sweep would snapshot.
fn warmed_session() -> (Session, usize) {
    let cfg = paper_soc(("dfmul", 2), ("dfadd", 1));
    let mut s = Session::new(cfg).unwrap();
    let a1 = s.tile_at(A1_POS.0, A1_POS.1);
    s.sample_every(100_000_000); // 100 us
    s.stage(a1, 1)
        .unwrap()
        .perf_only()
        .with_tg_load(4)
        .warmup(ms(2));
    (s, a1)
}

fn continue_and_measure(s: &mut Session, tile: usize) -> (PhaseReport, Fingerprint) {
    let report = s.measure(tile, ms(3)).unwrap();
    (report, fingerprint(s.soc()))
}

#[test]
fn fork_with_unchanged_frequencies_is_bit_identical() {
    let (mut original, a1) = warmed_session();
    let before = fingerprint(original.soc());
    let snap: SocSnapshot = original.snapshot().unwrap();

    // Taking the snapshot must not perturb the original.
    assert_eq!(fingerprint(original.soc()), before);
    assert_eq!(fingerprint(snap.soc()), before);
    assert_eq!(snap.now(), original.soc().now);

    // Continue the original and two independent resumes identically.
    let (rep_orig, fp_orig) = continue_and_measure(&mut original, a1);
    let mut fork_a = Session::resume(&snap).unwrap();
    let mut fork_b = Session::resume(&snap).unwrap();
    let (rep_a, fp_a) = continue_and_measure(&mut fork_a, a1);
    let (rep_b, fp_b) = continue_and_measure(&mut fork_b, a1);

    assert_eq!(rep_orig, rep_a, "PhaseReports must match exactly");
    assert_eq!(rep_orig, rep_b, "snapshots must be reusable");
    assert_eq!(fp_orig, fp_a);
    assert_eq!(fp_orig, fp_b);
    assert!(rep_orig.invocations > 0, "workload actually ran");
    assert!(
        fp_orig.sampler.as_ref().unwrap()[0].1.len() > 20,
        "sampler traces compared"
    );
}

#[test]
fn fork_preserves_staged_blocks() {
    let (original, a1) = warmed_session();
    let snap = original.snapshot().unwrap();
    let fork = Session::resume(&snap).unwrap();
    assert_eq!(original.staged(a1), fork.staged(a1));
    assert!(!fork.staged(a1).is_empty());
}

/// A snapshot taken while a DFS actuator swap is still in flight must
/// capture the pending retime: both branches swap on the same edge.
#[test]
fn fork_mid_dfs_retune_is_bit_identical() {
    let (mut original, a1) = warmed_session();
    // Request A1: 50 -> 20 MHz; the dual-MMCM actuator swaps ~11 us
    // later, so a snapshot right after the write is mid-retune.
    original.freq(ISL_A1, 20).unwrap();
    let snap = original.snapshot().unwrap();
    let (rep_orig, fp_orig) = continue_and_measure(&mut original, a1);
    let mut fork = Session::resume(&snap).unwrap();
    let (rep_fork, fp_fork) = continue_and_measure(&mut fork, a1);
    assert_eq!(rep_orig, rep_fork);
    assert_eq!(fp_orig, fp_fork);
    assert_eq!(fp_fork.freq_mhz[ISL_A1], 20, "the retune really landed");
}

/// WarmFork results must sit within the stated tolerance of the Cold
/// reference across a >= 12-point frequency sweep: <= 20% per point and
/// <= 10% on average (see docs/PERF.md for why warm points are
/// tolerance-gated rather than bit-exact).
#[test]
fn warm_fork_sweep_is_within_tolerance_of_cold() {
    // One replica (no lockstep completion bursts) and wide windows
    // (>= 12 invocations per point) keep fixed-window quantization well
    // under the gated tolerance.
    let mut p = SweepParams::quick("dfmul");
    p.replications = vec![1];
    p.accel_mhz = vec![25, 30, 35, 40, 45, 50];
    p.noc_mhz = vec![50, 100];
    p.warmup = 1_000_000_000; // 1 ms
    p.window = 12_000_000_000; // 12 ms
    assert!(p.specs().len() >= 12, "frequency sweep must cover >= 12 points");

    clear_memo();
    p.mode = SweepMode::Cold;
    let cold = sweep_replication(&p).unwrap();
    p.mode = SweepMode::WarmFork;
    let warm = sweep_replication(&p).unwrap();
    assert_eq!(cold.len(), warm.len());
    assert!(memo_len() >= cold.len() + warm.len(), "both sweeps memoized");

    let mut rel_sum = 0.0;
    for (c, w) in cold.iter().zip(&warm) {
        // Identity and area must agree exactly; throughput within
        // tolerance.
        assert_eq!(
            (c.accel.as_str(), c.replicas, c.accel_mhz, c.noc_mhz, c.near_mem),
            (w.accel.as_str(), w.replicas, w.accel_mhz, w.noc_mhz, w.near_mem)
        );
        assert_eq!(c.area, w.area);
        assert!(c.throughput_mbs > 0.0 && w.throughput_mbs > 0.0);
        let rel = (c.throughput_mbs - w.throughput_mbs).abs() / c.throughput_mbs;
        assert!(
            rel <= 0.20,
            "point {}@{}MHz/noc{}MHz: cold {:.3} vs warm {:.3} MB/s ({:.1}% off)",
            c.accel,
            c.accel_mhz,
            c.noc_mhz,
            c.throughput_mbs,
            w.throughput_mbs,
            rel * 100.0
        );
        rel_sum += rel;
        // Observability: warm points report the (longer) shared warmup
        // they actually rest on, and the same effective window.
        assert_eq!(c.eff_window_ps, w.eff_window_ps);
        assert!(w.eff_warmup_ps > 0);
    }
    let rel_mean = rel_sum / cold.len() as f64;
    assert!(
        rel_mean <= 0.10,
        "mean warm-vs-cold deviation {:.1}% exceeds 10%",
        rel_mean * 100.0
    );

    // Memoization: re-running either sweep must hit the cache (tested
    // here via the identical results contract).
    let warm2 = sweep_replication(&p).unwrap();
    assert_eq!(warm, warm2, "memoized re-run must be identical");
}
