//! System tests for the fault-injection + resilience subsystem — the
//! ISSUE's acceptance criteria: (a) a faulted cluster run is
//! bit-identical across `--threads {1,2,0}`, (b) an empty
//! [`FaultPlan`] (and idle resilience machinery) is bit-identical to
//! the pre-fault engine across all three [`EngineMode`]s, (c) a
//! mid-run replica crash under retry + health checks ends SLO-met
//! with >= 90% of interrupted requests rescued while the
//! no-resilience baseline misses the SLO — plus the drain-deadline
//! force-retire regression.

use vespa::cluster::{AutoscaleSpec, ClusterSpec};
use vespa::config::SocConfig;
use vespa::fault::{Fault, FaultPlan, HealthSpec, RetrySpec};
use vespa::scenario::{ms, Scenario, Session};
use vespa::serve::{Arrival, DispatchPolicy, ServeSpec};
use vespa::sim::EngineMode;
use vespa::util::Ps;

const US: Ps = 1_000_000;

/// One 2-replica dfmul tile on a governable island — the same
/// per-replica SoC as the cluster suite (~4250 req/s at 50 MHz), so
/// capacity math carries over.
fn fleet_cfg(accel_mhz: u64) -> SocConfig {
    Scenario::grid(2, 2)
        .name("fault-2x2")
        .seed(0xE5B)
        .island("noc", 100)
        .island_dfs("acc", accel_mhz, 10..=50, 5)
        .noc_island("noc")
        .mem_at(0, 0)
        .accel_at(1, 0, "dfmul", 2, "acc")
        .io_at_on(0, 1, "noc")
        .build()
        .unwrap()
}

/// Node index of the accelerator tile (the fault plans' `t<N>` target).
fn accel_tile() -> usize {
    Session::new(fleet_cfg(50)).unwrap().mra_tiles()[0]
}

// ---------------------------------------------------------------------
// (a) Thread invariance: the faulted cluster path is bit-identical on
//     the serial reference, a small pool, and all cores.
// ---------------------------------------------------------------------

#[test]
fn faulted_cluster_is_thread_invariant() {
    let t = accel_tile();
    // Every fault kind that survives to the cluster layer: a hang, a
    // replica-targeted slowdown, a stuck DFS actuator, and an injected
    // crash — under retry, health checks, and the autoscaler at once.
    let plan = FaultPlan::parse(&format!(
        "hang@t{t}:at=10ms,dur=4ms;slow@t{t}@r1:at=20ms,dur=10ms,factor=4;\
         stuck@i1:at=5ms,dur=30ms;crash@r0:at=40ms"
    ))
    .unwrap();
    let spec = ServeSpec::new(Arrival::Poisson { rps: 6000.0 }, ms(100))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0xFA17)
        .faults(plan)
        .retry(RetrySpec::new(4, 500 * US));
    let cspec = ClusterSpec::new(3, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .autoscale(AutoscaleSpec::new(2))
        .health(HealthSpec::new())
        .drain_deadline(ms(20));

    let r1 = cspec.clone().threads(1).run(fleet_cfg(50)).unwrap();
    let r2 = cspec.clone().threads(2).run(fleet_cfg(50)).unwrap();
    let r0 = cspec.threads(0).run(fleet_cfg(50)).unwrap();

    assert!(r1.completed > 100, "enough traffic to be meaningful");
    assert!(r1.faults.injected >= 4, "the whole plan resolved: {:?}", r1.faults);
    assert_eq!(r1, r2, "2 workers drifted from the serial reference");
    assert_eq!(r1, r0, "all-cores drifted from the serial reference");
}

// ---------------------------------------------------------------------
// (b) Empty plan + idle resilience = bit-identical to the pre-fault
//     engine, on every engine mode.
// ---------------------------------------------------------------------

#[test]
fn empty_plan_is_bit_identical_across_engine_modes() {
    // 800 rps against a ~4250 req/s SoC: nothing drops, so an armed
    // retry policy and an empty fault plan must leave no trace — the
    // report (ledger included) matches a run without either, on the
    // reference, idle-aware, and event-driven engines alike.
    let base = ServeSpec::new(Arrival::Poisson { rps: 800.0 }, ms(40))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .seed(0xBA5E);
    let run = |spec: &ServeSpec, mode: EngineMode| {
        let mut s = Session::new(fleet_cfg(50)).unwrap();
        s.engine(mode);
        s.serve(spec).unwrap()
    };
    let baseline = run(&base, EngineMode::default());
    assert!(baseline.completed > 20, "enough traffic to be meaningful");
    assert!(baseline.faults.is_empty(), "fault-free ledger stays zero");

    let armed = base
        .clone()
        .faults(FaultPlan::new())
        .retry(RetrySpec::new(3, 500 * US).deadline(ms(50)));
    for mode in [
        EngineMode::Reference,
        EngineMode::IdleAware,
        EngineMode::EventDriven,
    ] {
        assert_eq!(
            run(&armed, mode),
            baseline,
            "empty plan + idle retry drifted on {mode:?}"
        );
    }
}

#[test]
fn idle_health_checks_leave_cluster_reports_unchanged() {
    let spec = ServeSpec::new(Arrival::Poisson { rps: 5000.0 }, ms(60))
        .policy(DispatchPolicy::JoinShortestQueue)
        .slo(ms(5))
        .sample_interval(ms(2))
        .seed(0x1D1E);
    let plain = ClusterSpec::new(2, spec.clone())
        .balancer(DispatchPolicy::JoinShortestQueue)
        .run(fleet_cfg(50))
        .unwrap();
    // Health checks watch a healthy fleet; the drain deadline bounds a
    // drain that never happens. Bit-identical, ledger and all.
    let armed = ClusterSpec::new(2, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .health(HealthSpec::new())
        .drain_deadline(ms(10))
        .run(fleet_cfg(50))
        .unwrap();
    assert!(plain.completed > 100, "enough traffic to be meaningful");
    assert_eq!(plain, armed, "idle resilience machinery left a trace");
}

// ---------------------------------------------------------------------
// (c) Mid-run crash: retry + health checks rescue the interrupted
//     requests and keep the SLO; the bare fleet misses it.
// ---------------------------------------------------------------------

#[test]
fn crash_with_retry_and_health_meets_slo_where_baseline_misses() {
    let t = accel_tile();
    // Slot 0's tile wedges at 36 ms (so its queue is provably
    // non-empty), then the whole replica crashes at 40 ms. 6000 rps is
    // comfortable for two ~4250 req/s replicas and hopeless for one.
    let plan = FaultPlan::parse(&format!("hang@t{t}@r0:at=36ms,dur=4ms;crash@r0:at=40ms")).unwrap();
    let spec = |resilient: bool| {
        let s = ServeSpec::new(Arrival::Poisson { rps: 6000.0 }, ms(200))
            .policy(DispatchPolicy::JoinShortestQueue)
            .slo(ms(5))
            .sample_interval(ms(2))
            .seed(0x5AFE)
            .faults(plan.clone());
        if resilient {
            s.retry(RetrySpec::new(4, 500 * US))
        } else {
            s
        }
    };

    let resilient = ClusterSpec::new(2, spec(true))
        .balancer(DispatchPolicy::RoundRobin)
        .health(HealthSpec::new())
        .run(fleet_cfg(50))
        .unwrap();
    assert_eq!(
        resilient.slo_met,
        Some(true),
        "resilient p95 {:.3} ms ({:?})",
        resilient.latency.p95_ms(),
        resilient.faults
    );
    assert!(resilient.faults.retried > 0, "{:?}", resilient.faults);
    assert!(resilient.faults.detected >= 1, "{:?}", resilient.faults);
    assert!(
        resilient.faults.failed_over >= 1,
        "warm standby replaced the crashed slot: {:?}",
        resilient.faults
    );
    assert!(
        resilient.faults.rescued_fraction() >= 0.9,
        "rescued {:.3}: {:?}",
        resilient.faults.rescued_fraction(),
        resilient.faults
    );
    // The crashed slot came back: two activations on slot 0.
    assert!(
        resilient.per_replica[0].activations >= 2,
        "{:#?}",
        resilient.per_replica[0]
    );

    let baseline = ClusterSpec::new(2, spec(false))
        .balancer(DispatchPolicy::RoundRobin)
        .run(fleet_cfg(50))
        .unwrap();
    assert_eq!(
        baseline.slo_met,
        Some(false),
        "baseline p95 {:.3} ms",
        baseline.latency.p95_ms()
    );
    assert!(baseline.faults.lost > 0, "{:?}", baseline.faults);
    assert_eq!(baseline.faults.rescued, 0, "{:?}", baseline.faults);
    assert!(
        resilient.completed > baseline.completed,
        "resilient {} vs baseline {}",
        resilient.completed,
        baseline.completed
    );
}

// ---------------------------------------------------------------------
// Drain deadline: a wedged draining replica is force-retired instead
// of blocking scale-down forever.
// ---------------------------------------------------------------------

#[test]
fn drain_deadline_force_retires_wedged_replica() {
    let t = accel_tile();
    // A 15 ms burst at 16000 rps pegs both queues, then every tile
    // hangs for the rest of the load window: the post-burst calm makes
    // the autoscaler drain a victim whose queue can never empty.
    let plan = FaultPlan::new().with(Fault::TileHang {
        tile: t,
        replica: None,
        at: ms(15),
        dur: ms(45),
    });
    let spec = ServeSpec::new(
        Arrival::Burst {
            base_rps: 400.0,
            burst_rps: 16_000.0,
            period: ms(60),
            duty: 0.25,
        },
        ms(60),
    )
    .policy(DispatchPolicy::JoinShortestQueue)
    .slo(ms(5))
    .sample_interval(ms(2))
    .seed(0xD0A1)
    .faults(plan);
    // Judge calm purely on the latency window so the wedged backlog
    // cannot veto the scale-down this test needs.
    let auto = AutoscaleSpec {
        down_windows: 1,
        backlog_high: f64::INFINITY,
        backlog_low: f64::INFINITY,
        ..AutoscaleSpec::new(1)
    };

    let bounded = ClusterSpec::new(2, spec.clone())
        .balancer(DispatchPolicy::JoinShortestQueue)
        .autoscale(auto.clone())
        .drain_deadline(ms(10))
        .run(fleet_cfg(50))
        .unwrap();
    assert!(
        bounded.faults.evicted >= 1,
        "wedged drain must force-retire: {:?} (actions {:?})",
        bounded.faults,
        bounded.autoscale_actions
    );
    assert!(bounded.faults.lost > 0, "{:?}", bounded.faults);
    let forced: u64 = bounded.per_replica.iter().map(|r| r.dropped).sum();
    assert!(forced > 0, "force-dropped queue counts as replica drops");

    // Without a deadline the victim just keeps draining until the hang
    // lifts — no eviction, nothing force-dropped.
    let unbounded = ClusterSpec::new(2, spec)
        .balancer(DispatchPolicy::JoinShortestQueue)
        .autoscale(auto)
        .run(fleet_cfg(50))
        .unwrap();
    assert_eq!(unbounded.faults.evicted, 0, "{:?}", unbounded.faults);
}
