//! Integration tests for the Scenario/Session API: builder validation
//! ergonomics, session phase semantics over the full simulator, and
//! parallel-vs-serial sweep equivalence.

use vespa::config::presets::{paper_soc, A1_POS};
use vespa::config::TileKind;
use vespa::dse::{sweep_replication, sweep_replication_serial, SweepParams};
use vespa::scenario::{ms, Scenario, ScenarioSet, ScenarioSpec, Session};

fn base() -> Scenario {
    Scenario::grid(3, 3)
        .island_dfs("noc", 100, 10..=100, 5)
        .island_dfs("acc", 50, 10..=50, 5)
        .island("sys", 50)
}

// ---------------------------------------------------------------------
// Builder validation: each failure mode yields a distinct, actionable
// message.
// ---------------------------------------------------------------------

#[test]
fn overlapping_tiles_error_names_cell_and_kinds() {
    let err = base()
        .mem_at(0, 0)
        .accel_at(0, 0, "dfmul", 1, "acc")
        .fill_tg("sys")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("(0, 0)"), "{err}");
    assert!(err.contains("already holds a MEM tile"), "{err}");
    assert!(err.contains("accelerator"), "{err}");
}

#[test]
fn island_index_out_of_range_error_counts_islands() {
    let err = base()
        .mem_at(0, 0)
        .accel_at(1, 1, "dfmul", 1, 9usize)
        .fill_tg("sys")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("island index 9 out of range"), "{err}");
    assert!(err.contains("3 island(s) declared"), "{err}");
    assert!(err.contains("\"noc\""), "{err}");
}

#[test]
fn unknown_island_name_error_lists_alternatives() {
    let err = base()
        .mem_at(0, 0)
        .tg_at(1, 0, "warp")
        .fill_tg("sys")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("no island named \"warp\""), "{err}");
    assert!(err.contains(".island_dfs()"), "{err}");
}

#[test]
fn missing_mem_error_suggests_mem_at() {
    let err = base().fill_tg("sys").build().unwrap_err().to_string();
    assert!(err.contains("no MEM tile"), "{err}");
    assert!(err.contains(".mem_at"), "{err}");
}

#[test]
fn zero_replica_error_names_the_accelerator() {
    let err = base()
        .mem_at(0, 0)
        .accel_at(2, 2, "dfsin", 0, "acc")
        .fill_tg("sys")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("zero replicas"), "{err}");
    assert!(err.contains("\"dfsin\""), "{err}");
    assert!(err.contains("(2, 2)"), "{err}");
}

#[test]
fn the_errors_are_mutually_distinct() {
    let errs: Vec<String> = vec![
        base()
            .mem_at(0, 0)
            .mem_at(0, 0)
            .fill_tg("sys")
            .build()
            .unwrap_err()
            .to_string(),
        base()
            .mem_at(0, 0)
            .tg_at(1, 1, 9usize)
            .fill_tg("sys")
            .build()
            .unwrap_err()
            .to_string(),
        base().fill_tg("sys").build().unwrap_err().to_string(),
        base()
            .mem_at(0, 0)
            .accel_at(1, 1, "gsm", 0, "acc")
            .fill_tg("sys")
            .build()
            .unwrap_err()
            .to_string(),
    ];
    for i in 0..errs.len() {
        for j in (i + 1)..errs.len() {
            assert_ne!(errs[i], errs[j], "error messages must be distinct");
        }
    }
}

// ---------------------------------------------------------------------
// Builder output drives the real simulator.
// ---------------------------------------------------------------------

#[test]
fn built_scenario_simulates_end_to_end() {
    let cfg = base()
        .mem_at(0, 0)
        .cpu_at_on(1, 0, "sys")
        .accel_at(2, 2, "dfmul", 2, "acc")
        .fill_tg("sys")
        .build()
        .unwrap();
    assert_eq!(cfg.tiles.len(), 9);
    let mut session = Session::new(cfg).unwrap();
    let tile = session.tile_at(2, 2);
    session.stage(tile, 1).unwrap().perf_only().warmup(ms(2));
    let report = session.measure(tile, ms(5)).unwrap();
    assert!(report.invocations > 0, "{report:?}");
    assert!(report.throughput_mbs > 1.0, "{report:?}");
    assert!(report.rtt_ns > 0.0, "{report:?}");
}

#[test]
fn preset_is_reproduced_by_the_builder() {
    // paper_soc is a thin preset over the builder; its shape must be
    // unchanged from the hand-rolled original.
    let cfg = paper_soc(("dfsin", 1), ("gsm", 2));
    cfg.validate().unwrap();
    assert_eq!((cfg.width, cfg.height), (4, 4));
    assert_eq!(cfg.islands.len(), 5);
    assert_eq!(cfg.tiles_where(|k| *k == TileKind::Tg).len(), 11);
    let a1 = &cfg.tiles[cfg.node_of(A1_POS.0, A1_POS.1)];
    assert_eq!(
        a1.kind,
        TileKind::Accel {
            accel: "dfsin".into(),
            replicas: 1
        }
    );
}

// ---------------------------------------------------------------------
// Parallel scenario evaluation.
// ---------------------------------------------------------------------

/// `ScenarioSet::run_parallel` must produce bit-identical `DsePoint`s to
/// the serial path (each scenario is an independent seeded simulation).
#[test]
fn parallel_sweep_is_bit_identical_to_serial() {
    let mut p = SweepParams::quick("dfmul");
    p.replications = vec![1, 2];
    p.accel_mhz = vec![25, 50];
    p.placements = vec![true, false];
    p.warmup = 500_000_000;
    p.window = 3_000_000_000;
    assert!(p.specs().len() >= 8, "sweep must cover >= 8 points");

    let serial = sweep_replication_serial(&p).unwrap();
    let parallel = sweep_replication(&p).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, q)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(s, q, "point #{i} diverged between serial and parallel");
    }
}

#[test]
fn explicit_thread_counts_agree_too() {
    let specs: Vec<ScenarioSpec> = [1usize, 2, 4]
        .iter()
        .map(|&k| {
            ScenarioSpec::new("dfadd", k)
                .warmup(500_000_000)
                .window(2_000_000_000)
        })
        .collect();
    let set = ScenarioSet::new(specs);
    let one = set.run_with_threads(1, vespa::dse::evaluate_point).unwrap();
    let many = set.run_with_threads(3, vespa::dse::evaluate_point).unwrap();
    assert_eq!(one, many);
    // Replication helps dfadd: monotone non-decreasing throughput.
    assert!(many[1].throughput_mbs > many[0].throughput_mbs * 1.2);
}
