"""Kernel-vs-oracle correctness: the CORE numerics signal.

Every Pallas kernel is checked against its independent ref.py oracle on
fixed seeds, edge values, and hypothesis-generated inputs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ADPCM_BLOCK_SHAPE,
    DF_BLOCK_SHAPE,
    GSM_FRAME_SHAPE,
    adpcm_block,
    dfadd_block,
    dfmul_block,
    dfsin_block,
    gsm_block,
)
from compile.kernels import ref

RNG = np.random.default_rng(0xC0FFEE)


def _rand_f32(shape, lo=-1e3, hi=1e3, rng=RNG):
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


# ---------------------------------------------------------------- dfadd ---


class TestDfadd:
    def test_matches_oracle(self):
        a = _rand_f32(DF_BLOCK_SHAPE)
        b = _rand_f32(DF_BLOCK_SHAPE)
        np.testing.assert_allclose(
            np.asarray(dfadd_block(a, b)), ref.dfadd_ref(a, b), rtol=1e-6
        )

    def test_zeros(self):
        z = np.zeros(DF_BLOCK_SHAPE, np.float32)
        np.testing.assert_array_equal(np.asarray(dfadd_block(z, z)), z)

    def test_negatives_cancel(self):
        a = _rand_f32(DF_BLOCK_SHAPE)
        out = np.asarray(dfadd_block(a, -a))
        np.testing.assert_allclose(out, np.zeros(DF_BLOCK_SHAPE), atol=1e-6)

    def test_inf_propagates(self):
        a = np.full(DF_BLOCK_SHAPE, np.inf, np.float32)
        b = np.ones(DF_BLOCK_SHAPE, np.float32)
        assert np.all(np.isinf(np.asarray(dfadd_block(a, b))))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-3, 1.0, 1e6]))
    def test_hypothesis_sweep(self, seed, scale):
        rng = np.random.default_rng(seed)
        a = _rand_f32(DF_BLOCK_SHAPE, -scale, scale, rng)
        b = _rand_f32(DF_BLOCK_SHAPE, -scale, scale, rng)
        np.testing.assert_allclose(
            np.asarray(dfadd_block(a, b)), ref.dfadd_ref(a, b), rtol=1e-5, atol=1e-6 * scale
        )


# ---------------------------------------------------------------- dfmul ---


class TestDfmul:
    def test_matches_oracle(self):
        a = _rand_f32(DF_BLOCK_SHAPE)
        b = _rand_f32(DF_BLOCK_SHAPE)
        np.testing.assert_allclose(
            np.asarray(dfmul_block(a, b)), ref.dfmul_ref(a, b), rtol=1e-6
        )

    def test_identity(self):
        a = _rand_f32(DF_BLOCK_SHAPE)
        one = np.ones(DF_BLOCK_SHAPE, np.float32)
        np.testing.assert_allclose(np.asarray(dfmul_block(a, one)), a, rtol=1e-7)

    def test_zero_annihilates(self):
        a = _rand_f32(DF_BLOCK_SHAPE)
        z = np.zeros(DF_BLOCK_SHAPE, np.float32)
        np.testing.assert_array_equal(
            np.asarray(dfmul_block(a, z)), np.zeros(DF_BLOCK_SHAPE, np.float32)
        )

    def test_sign_rules(self):
        a = np.full(DF_BLOCK_SHAPE, -2.0, np.float32)
        b = np.full(DF_BLOCK_SHAPE, 3.0, np.float32)
        np.testing.assert_allclose(
            np.asarray(dfmul_block(a, b)), np.full(DF_BLOCK_SHAPE, -6.0), rtol=0
        )

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, seed):
        rng = np.random.default_rng(seed)
        a = _rand_f32(DF_BLOCK_SHAPE, rng=rng)
        b = _rand_f32(DF_BLOCK_SHAPE, rng=rng)
        np.testing.assert_allclose(
            np.asarray(dfmul_block(a, b)), ref.dfmul_ref(a, b), rtol=1e-5
        )


# ---------------------------------------------------------------- dfsin ---


class TestDfsin:
    def test_matches_oracle_primary_range(self):
        x = _rand_f32(DF_BLOCK_SHAPE, -np.pi, np.pi)
        np.testing.assert_allclose(
            np.asarray(dfsin_block(x)), ref.dfsin_ref(x), rtol=1e-4, atol=1e-6
        )

    def test_matches_oracle_wide_range(self):
        # Range reduction over several periods.
        x = _rand_f32(DF_BLOCK_SHAPE, -50.0, 50.0)
        np.testing.assert_allclose(
            np.asarray(dfsin_block(x)), ref.dfsin_ref(x), rtol=1e-3, atol=1e-5
        )

    def test_zeros(self):
        z = np.zeros(DF_BLOCK_SHAPE, np.float32)
        np.testing.assert_allclose(np.asarray(dfsin_block(z)), z, atol=1e-7)

    def test_odd_symmetry(self):
        x = _rand_f32(DF_BLOCK_SHAPE, -10.0, 10.0)
        np.testing.assert_allclose(
            np.asarray(dfsin_block(x)), -np.asarray(dfsin_block(-x)), atol=1e-6
        )

    def test_special_angles(self):
        x = np.zeros(DF_BLOCK_SHAPE, np.float32)
        x[0, 0] = np.pi / 2
        x[0, 1] = -np.pi / 2
        x[0, 2] = np.pi
        out = np.asarray(dfsin_block(x))
        assert abs(out[0, 0] - 1.0) < 1e-6
        assert abs(out[0, 1] + 1.0) < 1e-6
        assert abs(out[0, 2]) < 1e-5

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        span=st.sampled_from([0.1, 3.14, 20.0]),
    )
    def test_hypothesis_sweep(self, seed, span):
        rng = np.random.default_rng(seed)
        x = _rand_f32(DF_BLOCK_SHAPE, -span, span, rng)
        np.testing.assert_allclose(
            np.asarray(dfsin_block(x)), ref.dfsin_ref(x), rtol=1e-3, atol=1e-5
        )


# ---------------------------------------------------------------- adpcm ---


class TestAdpcm:
    def test_matches_oracle(self):
        x = RNG.integers(-32768, 32768, size=ADPCM_BLOCK_SHAPE).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(adpcm_block(x)), ref.adpcm_ref(x))

    def test_silence_encodes_zero(self):
        z = np.zeros(ADPCM_BLOCK_SHAPE, np.int32)
        np.testing.assert_array_equal(np.asarray(adpcm_block(z)), ref.adpcm_ref(z))

    def test_codes_are_4bit(self):
        x = RNG.integers(-32768, 32768, size=ADPCM_BLOCK_SHAPE).astype(np.int32)
        out = np.asarray(adpcm_block(x))
        assert out.min() >= 0 and out.max() <= 15

    def test_full_scale_step(self):
        x = np.zeros(ADPCM_BLOCK_SHAPE, np.int32)
        x[0, :] = 32767
        x[1, :] = -32768
        np.testing.assert_array_equal(np.asarray(adpcm_block(x)), ref.adpcm_ref(x))

    def test_ramp(self):
        t = np.arange(ADPCM_BLOCK_SHAPE[0], dtype=np.int32)[:, None]
        x = np.broadcast_to(t * 257 - 8000, ADPCM_BLOCK_SHAPE).astype(np.int32).copy()
        np.testing.assert_array_equal(np.asarray(adpcm_block(x)), ref.adpcm_ref(x))

    def test_sine_wave_input(self):
        t = np.arange(ADPCM_BLOCK_SHAPE[0])[:, None]
        c = np.arange(ADPCM_BLOCK_SHAPE[1])[None, :]
        x = (10000 * np.sin(0.1 * t + 0.05 * c)).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(adpcm_block(x)), ref.adpcm_ref(x))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), amp=st.sampled_from([5, 500, 32767]))
    def test_hypothesis_sweep(self, seed, amp):
        rng = np.random.default_rng(seed)
        x = rng.integers(-amp - 1, amp + 1, size=ADPCM_BLOCK_SHAPE).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(adpcm_block(x)), ref.adpcm_ref(x))


# ------------------------------------------------------------------ gsm ---


class TestGsmAcf:
    def test_matches_oracle(self):
        x = _rand_f32(GSM_FRAME_SHAPE, -1.0, 1.0)
        np.testing.assert_allclose(
            np.asarray(gsm_block(x)), ref.gsm_acf_ref(x), rtol=1e-4, atol=1e-4
        )

    def test_lag0_is_energy(self):
        x = _rand_f32(GSM_FRAME_SHAPE, -1.0, 1.0)
        out = np.asarray(gsm_block(x))
        np.testing.assert_allclose(
            out[0, :], np.sum(x.astype(np.float64) ** 2, axis=0), rtol=1e-4
        )

    def test_padding_rows_zero(self):
        x = _rand_f32(GSM_FRAME_SHAPE, -1.0, 1.0)
        out = np.asarray(gsm_block(x))
        np.testing.assert_array_equal(out[9:, :], np.zeros_like(out[9:, :]))

    def test_constant_signal(self):
        x = np.ones(GSM_FRAME_SHAPE, np.float32)
        out = np.asarray(gsm_block(x))
        n = GSM_FRAME_SHAPE[0]
        for k in range(9):
            np.testing.assert_allclose(out[k, :], float(n - k), rtol=1e-6)

    def test_acf_peak_at_lag0(self):
        x = _rand_f32(GSM_FRAME_SHAPE, -1.0, 1.0)
        out = np.asarray(gsm_block(x))
        assert np.all(out[0, :] >= np.abs(out[1:9, :]).max(axis=0) - 1e-3)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, seed):
        rng = np.random.default_rng(seed)
        x = _rand_f32(GSM_FRAME_SHAPE, -4.0, 4.0, rng)
        np.testing.assert_allclose(
            np.asarray(gsm_block(x)), ref.gsm_acf_ref(x), rtol=1e-3, atol=1e-3
        )
