"""Layer-2 model shape/semantics tests + Levinson-Durbin vs oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import GSM_FRAME_SHAPE, ref


class TestInvocationRegistry:
    def test_all_five_accelerators_present(self):
        assert sorted(model.INVOCATIONS) == [
            "adpcm",
            "dfadd",
            "dfmul",
            "dfsin",
            "gsm",
        ]

    @pytest.mark.parametrize("name", sorted(model.INVOCATIONS))
    def test_shapes_are_8x128_aligned(self, name):
        _, specs = model.INVOCATIONS[name]
        for s in specs:
            assert s.shape[0] % 8 == 0, f"{name} sublane {s.shape}"
            assert s.shape[1] == 128, f"{name} lane {s.shape}"

    @pytest.mark.parametrize("name", sorted(model.INVOCATIONS))
    def test_invocations_run_and_match_declared_output(self, name):
        import jax

        fn, specs = model.INVOCATIONS[name]
        rng = np.random.default_rng(7)
        args = []
        for s in specs:
            if str(s.dtype) == "int32":
                args.append(rng.integers(-32768, 32768, s.shape).astype(np.int32))
            else:
                args.append(rng.uniform(-1, 1, s.shape).astype(np.float32))
        out = fn(*args)
        declared = jax.eval_shape(fn, *specs)
        assert len(out) == len(declared)
        for got, d in zip(out, declared):
            assert got.shape == d.shape
            assert got.dtype == d.dtype


class TestGsmReflection:
    def _frame(self, seed=3, scale=1.0):
        rng = np.random.default_rng(seed)
        return (scale * rng.uniform(-1, 1, GSM_FRAME_SHAPE)).astype(np.float32)

    def test_matches_levinson_oracle(self):
        x = self._frame()
        acf, refl = model.gsm_invocation(x)
        want = ref.gsm_reflection_ref(np.asarray(acf))
        np.testing.assert_allclose(np.asarray(refl), want, rtol=1e-3, atol=1e-4)

    def test_reflection_coeffs_stable(self):
        x = self._frame(seed=11)
        _, refl = model.gsm_invocation(x)
        assert np.all(np.abs(np.asarray(refl)) <= 1.0 + 1e-6)

    def test_silent_frame_zero_coeffs(self):
        z = np.zeros(GSM_FRAME_SHAPE, np.float32)
        _, refl = model.gsm_invocation(z)
        np.testing.assert_array_equal(np.asarray(refl), np.zeros((8, 128), np.float32))

    def test_strong_ar1_signal_first_coeff(self):
        # x[t] = rho * x[t-1] + eps  ->  k1 ~ -rho for small eps.
        rng = np.random.default_rng(5)
        rho = 0.9
        n, c = GSM_FRAME_SHAPE
        x = np.zeros((n, c), np.float64)
        eps = rng.normal(0, 0.05, (n, c))
        for t in range(1, n):
            x[t] = rho * x[t - 1] + eps[t]
        _, refl = model.gsm_invocation(x.astype(np.float32))
        k1 = np.asarray(refl)[0, :]
        assert np.mean(np.abs(k1 + rho)) < 0.1

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, seed):
        rng = np.random.default_rng(seed)
        x = rng.uniform(-2, 2, GSM_FRAME_SHAPE).astype(np.float32)
        acf, refl = model.gsm_invocation(x)
        want = ref.gsm_reflection_ref(np.asarray(acf))
        np.testing.assert_allclose(np.asarray(refl), want, rtol=5e-3, atol=5e-4)
