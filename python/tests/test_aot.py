"""AOT pipeline tests: lowering, HLO-text properties, manifest format."""

import pathlib

import pytest

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("name", sorted(model.INVOCATIONS))
    def test_lowers_to_hlo_text(self, name):
        text = aot.to_hlo_text(aot.lower_invocation(name))
        assert "ENTRY" in text
        assert "HloModule" in text

    def test_adpcm_hlo_keeps_large_constants(self):
        """Regression: the default printer elides the 89-entry step table
        to `{...}`, which the xla-crate's older HLO parser silently reads
        as zeros (every ADPCM code came out 7/15)."""
        text = aot.to_hlo_text(aot.lower_invocation("adpcm"))
        assert "7, 8, 9, 10, 11" in text, "step table must be printed in full"
        assert "-1, -1, -1, -1, 2, 4, 6, 8" in text, "index table too"

    def test_hlo_is_tupled(self):
        # aot lowers with return_tuple=True; the rust loader untuples.
        text = aot.to_hlo_text(aot.lower_invocation("dfadd"))
        assert "tuple(" in text


class TestManifest:
    def test_describe_io_format(self):
        lines = aot.describe_io("gsm")
        assert lines[0] == "input gsm 0 dtype=f32 shape=160x128"
        assert "output gsm 0 dtype=f32 shape=16x128" in lines
        assert "output gsm 1 dtype=f32 shape=8x128" in lines

    def test_describe_io_adpcm_int(self):
        lines = aot.describe_io("adpcm")
        assert lines[0] == "input adpcm 0 dtype=s32 shape=64x128"

    @pytest.mark.parametrize("name", sorted(model.INVOCATIONS))
    def test_io_lines_cover_all_streams(self, name):
        fn, specs = model.INVOCATIONS[name]
        lines = aot.describe_io(name)
        inputs = [l for l in lines if l.startswith("input")]
        assert len(inputs) == len(specs)


class TestArtifactsOnDisk:
    """Validate the checked-out artifacts directory when present."""

    @property
    def art_dir(self):
        return pathlib.Path(__file__).resolve().parents[2] / "artifacts"

    def test_manifest_matches_models(self):
        man = self.art_dir / "manifest.txt"
        if not man.exists():
            pytest.skip("run `make artifacts` first")
        text = man.read_text()
        for name in model.INVOCATIONS:
            assert f"module {name} file={name}.hlo.txt" in text
            assert (self.art_dir / f"{name}.hlo.txt").exists()

    def test_artifacts_contain_full_constants(self):
        f = self.art_dir / "adpcm.hlo.txt"
        if not f.exists():
            pytest.skip("run `make artifacts` first")
        text = f.read_text()
        assert "{...}" not in text, "elided constants would break the rust runtime"
