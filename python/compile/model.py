"""Layer-2 JAX compute graphs: one "accelerator invocation" per CHStone
accelerator, calling the Layer-1 Pallas kernels.

Each ``<name>_invocation`` is the function AOT-lowered to an HLO artifact
(see aot.py) and executed from the Rust simulator every time the modelled
accelerator finishes a DMA input block. Shapes are static — one artifact
per accelerator variant, as on the FPGA where each HLS accelerator has a
fixed streaming interface.
"""

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import (
    ADPCM_BLOCK_SHAPE,
    DF_BLOCK_SHAPE,
    GSM_FRAME_SHAPE,
    adpcm_block,
    dfadd_block,
    dfmul_block,
    dfsin_block,
    gsm_block,
)

GSM_ORDER = 8


def dfadd_invocation(a: jax.Array, b: jax.Array) -> Tuple[jax.Array]:
    """dfadd: two f32 (8,128) input streams -> one sum stream."""
    return (dfadd_block(a, b),)


def dfmul_invocation(a: jax.Array, b: jax.Array) -> Tuple[jax.Array]:
    """dfmul: two f32 (8,128) input streams -> one product stream."""
    return (dfmul_block(a, b),)


def dfsin_invocation(x: jax.Array) -> Tuple[jax.Array]:
    """dfsin: one f32 (8,128) input stream -> sin(x)."""
    return (dfsin_block(x),)


def adpcm_invocation(x: jax.Array) -> Tuple[jax.Array]:
    """adpcm: one int32 (64,128) PCM block -> 4-bit codes (one per i32)."""
    return (adpcm_block(x),)


def _gsm_reflection(acf: jax.Array) -> jax.Array:
    """Levinson-Durbin on the kernel's autocorrelation lags.

    The short (order-8) sequential recursion is control-dominated, so it
    stays in the L2 graph rather than the Pallas kernel — mirroring the
    HLS design where the MAC array is unrolled hardware and the recursion
    is a small FSM.
    """
    r = acf[:9, :]
    silent = r[0, :] <= 0.0
    err = jnp.where(silent, 1.0, r[0, :])
    a = jnp.zeros((GSM_ORDER + 1, acf.shape[1]), dtype=jnp.float32)
    a = a.at[0, :].set(1.0)
    refl_rows: List[jax.Array] = []
    for i in range(1, GSM_ORDER + 1):
        acc = r[i, :]
        for j in range(1, i):
            acc = acc + a[j, :] * r[i - j, :]
        k = jnp.where(silent | (err <= 0.0), 0.0, -acc / jnp.where(err > 0, err, 1.0))
        k = jnp.clip(k, -1.0, 1.0)
        refl_rows.append(k)
        a_new = a
        for j in range(1, i):
            a_new = a_new.at[j, :].set(a[j, :] + k * a[i - j, :])
        a_new = a_new.at[i, :].set(k)
        a = a_new
        err = err * (1.0 - k * k)
    return jnp.stack(refl_rows, axis=0)


def gsm_invocation(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """gsm LPC: one (160,128) frame block -> (acf (16,128), refl (8,128))."""
    acf = gsm_block(x)
    refl = _gsm_reflection(acf)
    return acf, refl


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, example input specs).
# The Rust runtime reads the same geometry from artifacts/manifest.txt.
# ---------------------------------------------------------------------------

def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


INVOCATIONS: Dict[str, Tuple[Callable, List[jax.ShapeDtypeStruct]]] = {
    "dfadd": (
        dfadd_invocation,
        [_spec(DF_BLOCK_SHAPE, jnp.float32), _spec(DF_BLOCK_SHAPE, jnp.float32)],
    ),
    "dfmul": (
        dfmul_invocation,
        [_spec(DF_BLOCK_SHAPE, jnp.float32), _spec(DF_BLOCK_SHAPE, jnp.float32)],
    ),
    "dfsin": (dfsin_invocation, [_spec(DF_BLOCK_SHAPE, jnp.float32)]),
    "adpcm": (adpcm_invocation, [_spec(ADPCM_BLOCK_SHAPE, jnp.int32)]),
    "gsm": (gsm_invocation, [_spec(GSM_FRAME_SHAPE, jnp.float32)]),
}
