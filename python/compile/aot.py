"""AOT bridge: lower every accelerator invocation to HLO *text*.

HLO text (not ``XlaComputation.serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs, per accelerator:
  artifacts/<name>.hlo.txt  — the lowered module
plus a single artifacts/manifest.txt describing each module's I/O
geometry in a line format the Rust runtime parses without a JSON dep:

  module <name> file=<name>.hlo.txt
  input <name> <index> dtype=<f32|s32> shape=<d0xd1>
  output <name> <index> dtype=<f32|s32> shape=<d0xd1>

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import INVOCATIONS

_DTYPE_NAMES = {"float32": "f32", "int32": "s32"}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides arrays above a size threshold as ``{...}``, which the xla-crate
    runtime's (older) HLO parser silently reads as zeros — observed as the
    adpcm step table turning into 89 zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_invocation(name: str):
    fn, specs = INVOCATIONS[name]
    return jax.jit(fn).lower(*specs)


def describe_io(name: str):
    """Manifest lines for one module: declared inputs + traced outputs."""
    fn, specs = INVOCATIONS[name]
    out = jax.eval_shape(fn, *specs)
    lines = []
    for i, s in enumerate(specs):
        dt = _DTYPE_NAMES[str(s.dtype)]
        shape = "x".join(str(d) for d in s.shape)
        lines.append(f"input {name} {i} dtype={dt} shape={shape}")
    for i, s in enumerate(out):
        dt = _DTYPE_NAMES[str(s.dtype)]
        shape = "x".join(str(d) for d in s.shape)
        lines.append(f"output {name} {i} dtype={dt} shape={shape}")
    return lines


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of accelerators"
    )
    args = parser.parse_args()

    names = sorted(INVOCATIONS)
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name in names:
        lowered = lower_invocation(name)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"module {name} file={name}.hlo.txt")
        manifest.extend(describe_io(name))
        print(f"lowered {name}: {len(text)} chars -> {path}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} lines, {len(names)} modules")


if __name__ == "__main__":
    main()
