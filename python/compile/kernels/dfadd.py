"""CHStone ``dfadd`` — software-emulated IEEE-754 double addition.

The HLS accelerator streams pairs of doubles and emits their sum. The
Pallas stand-in performs the same element-wise addition over one DMA block.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CHStone kernel
emulates *double* arithmetic in integer ops because the target fabric has
no FPU; on TPU the natural analogue is native f32 VPU arithmetic, so the
block dtype is float32 and numerics are validated against a float64 oracle
cast to f32.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One accelerator invocation = one (8, 128) f32 block per operand: 4 KiB
# in each of two input streams, 4 KiB out. 8x128 is the base VPU tile.
DF_BLOCK_SHAPE = (8, 128)


def _dfadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def dfadd_block(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise double-add over one DMA block (f32, (8, 128))."""
    return pl.pallas_call(
        _dfadd_kernel,
        out_shape=jax.ShapeDtypeStruct(DF_BLOCK_SHAPE, jnp.float32),
        interpret=True,
    )(a, b)
