"""CHStone ``gsm`` — GSM 06.10 LPC analysis (autocorrelation hot-spot).

CHStone's gsm runs the Linear Predictive Coding front end of the GSM
full-rate codec: per 160-sample frame, compute 9 autocorrelation lags and
derive 8 reflection coefficients by the Schur recursion. The
autocorrelation dominates the cycle count and is the Pallas hot-spot here;
the short sequential Schur recursion lives in the Layer-2 JAX wrapper
(model.py), exactly mirroring the HLS split between the unrolled MAC array
and the control-dominated recursion.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One invocation: a (160, 128) f32 block = one 160-sample frame for each
# of 128 independent channels. 160 = 20 sublanes of 8.
GSM_FRAME_SHAPE = (160, 128)
GSM_LAGS = 9
# Output padded to a sublane multiple: rows 0..8 hold r[0..8], rows 9..15
# are zero.
GSM_ACF_SHAPE = (16, 128)


def _gsm_acf_kernel(x_ref, o_ref):
    x = x_ref[...]
    n = x.shape[0]
    o_ref[...] = jnp.zeros(GSM_ACF_SHAPE, dtype=jnp.float32)
    for k in range(GSM_LAGS):
        # r[k] = sum_t x[t] * x[t+k]; static slices so the loop unrolls
        # into 9 VPU MAC chains, like the HLS unrolled lag array.
        prod = x[: n - k, :] * x[k:, :]
        o_ref[k, :] = jnp.sum(prod, axis=0)


def gsm_block(x: jax.Array) -> jax.Array:
    """Autocorrelation lags r[0..8] of one (160, 128) frame block.

    Returns a (16, 128) f32 block (rows 9..15 zero-padded).
    """
    return pl.pallas_call(
        _gsm_acf_kernel,
        out_shape=jax.ShapeDtypeStruct(GSM_ACF_SHAPE, jnp.float32),
        interpret=True,
    )(x)
