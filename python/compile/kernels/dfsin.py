"""CHStone ``dfsin`` — sine computed from emulated double add/mul chains.

CHStone's dfsin evaluates sin(x) with a Taylor series built on the dfadd /
dfmul emulation routines, which is why the HLS accelerator is deeply
compute-bound (throughput 0.33 MB/s in Table I, ~26x slower than dfadd).
The Pallas stand-in performs the same range-reduction + odd-polynomial
evaluation per element, vectorized across the VPU lanes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dfadd import DF_BLOCK_SHAPE

_TWO_PI = 6.283185307179586
_PI = 3.141592653589793

# Taylor coefficients for sin(r) = r - r^3/3! + r^5/5! - ... + r^15/15!,
# evaluated in Horner form over r^2. Max abs error over |r| <= pi is
# ~3e-8, below f32 epsilon-scale for the test tolerances.
_COEFFS = (
    -1.0 / 1307674368000.0,  # 1/15!
    1.0 / 6227020800.0,      # 1/13!
    -1.0 / 39916800.0,       # 1/11!
    1.0 / 362880.0,          # 1/9!
    -1.0 / 5040.0,           # 1/7!
    1.0 / 120.0,             # 1/5!
    -1.0 / 6.0,              # 1/3!
)


def _dfsin_kernel(x_ref, o_ref):
    x = x_ref[...]
    # Range reduction to r in [-pi, pi]: r = x - round(x / 2pi) * 2pi.
    k = jnp.round(x * (1.0 / _TWO_PI))
    r = x - k * _TWO_PI
    r2 = r * r
    # Horner over r^2, then multiply the odd factor back in.
    p = jnp.full_like(r2, _COEFFS[0])
    for c in _COEFFS[1:]:
        p = p * r2 + c
    o_ref[...] = r + r * r2 * p


def dfsin_block(x: jax.Array) -> jax.Array:
    """sin(x) over one DMA block (f32, (8, 128)), CHStone-style Taylor."""
    return pl.pallas_call(
        _dfsin_kernel,
        out_shape=jax.ShapeDtypeStruct(DF_BLOCK_SHAPE, jnp.float32),
        interpret=True,
    )(x)
