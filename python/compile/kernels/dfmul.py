"""CHStone ``dfmul`` — software-emulated IEEE-754 double multiplication.

Element-wise multiply over one DMA block; see dfadd.py for the TPU
adaptation rationale (f32 blocks standing in for emulated doubles).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dfadd import DF_BLOCK_SHAPE


def _dfmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * b_ref[...]


def dfmul_block(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise double-mul over one DMA block (f32, (8, 128))."""
    return pl.pallas_call(
        _dfmul_kernel,
        out_shape=jax.ShapeDtypeStruct(DF_BLOCK_SHAPE, jnp.float32),
        interpret=True,
    )(a, b)
