"""CHStone ``adpcm`` — IMA/DVI ADPCM encoder.

CHStone's adpcm compresses 16-bit PCM samples to 4-bit codes with the
classic IMA predictor (step-size table + predicted-value feedback). The
recurrence over time makes it compute-bound on the HLS fabric (1.40 MB/s
baseline in Table I).

TPU adaptation: the sample recurrence cannot be vectorized over time, so
the kernel scans the 64 time steps sequentially (fori_loop) while
vectorizing over 128 independent channels in the lane dimension — the same
trick the HLS tool uses (II=1 pipeline over time, parallel channels).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One invocation: (64 samples, 128 channels) int32 PCM in, 4-bit codes
# (stored one per int32) out. 64 is a sublane multiple of 8.
ADPCM_BLOCK_SHAPE = (64, 128)

# IMA ADPCM step-size table (89 entries), as in CHStone's adpcm.c.
IMA_STEP_TABLE = (
    7, 8, 9, 10, 11, 12, 13, 14, 16, 17,
    19, 21, 23, 25, 28, 31, 34, 37, 41, 45,
    50, 55, 60, 66, 73, 80, 88, 97, 107, 118,
    130, 143, 157, 173, 190, 209, 230, 253, 279, 307,
    337, 371, 408, 449, 494, 544, 598, 658, 724, 796,
    876, 963, 1060, 1166, 1282, 1411, 1552, 1707, 1878, 2066,
    2272, 2499, 2749, 3024, 3327, 3660, 4026, 4428, 4871, 5358,
    5894, 6484, 7132, 7845, 8630, 9493, 10442, 11487, 12635, 13899,
    15289, 16818, 18500, 20350, 22385, 24623, 27086, 29794, 32767,
)

# IMA index-adjustment table for the 3 magnitude bits of each code.
IMA_INDEX_TABLE = (-1, -1, -1, -1, 2, 4, 6, 8)


def _table_lookup(tab, idx):
    """Gather-free table lookup: one-hot select over the table axis.

    The AOT artifacts must execute bit-exactly on the (older) XLA runtime
    bundled with the Rust `xla` crate, whose dynamic-gather lowering was
    observed to diverge on s32 tables; a broadcast-compare-reduce is
    portable across every XLA vintage and vectorizes fine on the VPU.
    """
    import jax.numpy as _jnp

    onehot = idx[None, :] == _jnp.arange(tab.shape[0], dtype=_jnp.int32)[:, None]
    return _jnp.sum(_jnp.where(onehot, tab[:, None], 0), axis=0)


def _encode_step(sample, pred, index, step_tab, idx_tab):
    """One IMA encode step for a vector of channels.

    Returns (code, new_pred, new_index). All int32 vectors.
    """
    step = _table_lookup(step_tab, index)
    diff = sample - pred
    sign = jnp.where(diff < 0, 8, 0)
    diff = jnp.abs(diff)

    # Successive-approximation quantization (the three magnitude bits),
    # exactly as CHStone's adpcm_coder inner bit tests.
    code = jnp.zeros_like(sample)
    vpdiff = step >> 3

    bit4 = diff >= step
    code = code | jnp.where(bit4, 4, 0)
    diff = diff - jnp.where(bit4, step, 0)
    vpdiff = vpdiff + jnp.where(bit4, step, 0)
    step_h = step >> 1

    bit2 = diff >= step_h
    code = code | jnp.where(bit2, 2, 0)
    diff = diff - jnp.where(bit2, step_h, 0)
    vpdiff = vpdiff + jnp.where(bit2, step_h, 0)
    step_q = step >> 2

    bit1 = diff >= step_q
    code = code | jnp.where(bit1, 1, 0)
    vpdiff = vpdiff + jnp.where(bit1, step_q, 0)

    new_pred = jnp.where(sign > 0, pred - vpdiff, pred + vpdiff)
    new_pred = jnp.clip(new_pred, -32768, 32767)

    new_index = jnp.clip(index + _table_lookup(idx_tab, code & 7), 0, 88)
    return code | sign, new_pred, new_index


def _adpcm_kernel(x_ref, step_tab_ref, idx_tab_ref, o_ref):
    # Pallas forbids capturing constant arrays: the quantizer tables come
    # in as kernel operands (they would live in SMEM on a real TPU).
    step_tab = step_tab_ref[...]
    idx_tab = idx_tab_ref[...]
    nlanes = x_ref.shape[1]
    pred0 = jnp.zeros((nlanes,), dtype=jnp.int32)
    index0 = jnp.zeros((nlanes,), dtype=jnp.int32)

    def body(t, carry):
        pred, index = carry
        code, pred, index = _encode_step(x_ref[t, :], pred, index, step_tab, idx_tab)
        o_ref[t, :] = code
        return pred, index

    jax.lax.fori_loop(0, x_ref.shape[0], body, (pred0, index0))


def adpcm_block(x: jax.Array) -> jax.Array:
    """IMA ADPCM-encode one (64, 128) int32 PCM block to 4-bit codes."""
    step_tab = jnp.array(IMA_STEP_TABLE, dtype=jnp.int32)
    idx_tab = jnp.array(IMA_INDEX_TABLE, dtype=jnp.int32)
    return pl.pallas_call(
        _adpcm_kernel,
        out_shape=jax.ShapeDtypeStruct(ADPCM_BLOCK_SHAPE, jnp.int32),
        interpret=True,
    )(x, step_tab, idx_tab)
