"""Pure-jnp / numpy oracles for the Layer-1 kernels.

These are the correctness reference: independent implementations with no
Pallas, no shared helper code with the kernels (the ADPCM oracle is a
direct scalar transcription of CHStone's adpcm_coder C loop).
"""

import numpy as np

from .adpcm import IMA_INDEX_TABLE, IMA_STEP_TABLE


def dfadd_ref(a, b):
    return (np.asarray(a, dtype=np.float64) + np.asarray(b, dtype=np.float64)).astype(
        np.float32
    )


def dfmul_ref(a, b):
    return (np.asarray(a, dtype=np.float64) * np.asarray(b, dtype=np.float64)).astype(
        np.float32
    )


def dfsin_ref(x):
    return np.sin(np.asarray(x, dtype=np.float64)).astype(np.float32)


def adpcm_ref(x):
    """Scalar IMA ADPCM encoder, transcribed from CHStone adpcm_coder.

    x: (T, C) int array of PCM samples. Returns (T, C) int32 codes.
    """
    x = np.asarray(x, dtype=np.int64)
    t_steps, chans = x.shape
    out = np.zeros((t_steps, chans), dtype=np.int32)
    for c in range(chans):
        valpred = 0
        index = 0
        for t in range(t_steps):
            sample = int(x[t, c])
            step = IMA_STEP_TABLE[index]
            diff = sample - valpred
            sign = 8 if diff < 0 else 0
            if diff < 0:
                diff = -diff
            code = 0
            vpdiff = step >> 3
            if diff >= step:
                code |= 4
                diff -= step
                vpdiff += step
            step >>= 1
            if diff >= step:
                code |= 2
                diff -= step
                vpdiff += step
            step >>= 1
            if diff >= step:
                code |= 1
                vpdiff += step
            if sign:
                valpred -= vpdiff
            else:
                valpred += vpdiff
            valpred = max(-32768, min(32767, valpred))
            index += IMA_INDEX_TABLE[code]
            index = max(0, min(88, index))
            out[t, c] = code | sign
    return out


def gsm_acf_ref(x):
    """Autocorrelation lags r[0..8], zero-padded to 16 rows."""
    x = np.asarray(x, dtype=np.float64)
    n, chans = x.shape
    out = np.zeros((16, chans), dtype=np.float64)
    for k in range(9):
        out[k, :] = np.sum(x[: n - k, :] * x[k:, :], axis=0)
    return out.astype(np.float32)


def gsm_reflection_ref(acf):
    """Reflection coefficients k[1..8] from r[0..8] via Levinson-Durbin.

    acf: (>=9, C). Returns (8, C) float32. Channels with r[0] <= 0 yield
    all-zero coefficients (silent frame), as in GSM 06.10.
    """
    r = np.asarray(acf, dtype=np.float64)[:9, :]
    chans = r.shape[1]
    order = 8
    silent = r[0, :] <= 0.0
    refl = np.zeros((order, chans), dtype=np.float64)
    a = np.zeros((order + 1, chans), dtype=np.float64)
    a[0, :] = 1.0
    err = np.where(silent, 1.0, r[0, :])  # dummy 1.0 avoids div-by-zero
    for i in range(1, order + 1):
        acc = r[i, :].copy()
        for j in range(1, i):
            acc += a[j, :] * r[i - j, :]
        k = np.where(silent | (err <= 0.0), 0.0, -acc / np.where(err > 0, err, 1.0))
        k = np.clip(k, -1.0, 1.0)
        refl[i - 1, :] = k
        a_new = a.copy()
        for j in range(1, i):
            a_new[j, :] = a[j, :] + k * a[i - j, :]
        a_new[i, :] = k
        a = a_new
        err = err * (1.0 - k * k)
    refl[:, silent] = 0.0
    return refl.astype(np.float32)
