"""Layer-1 Pallas kernels for the five CHStone accelerators.

Each module exposes a ``<name>_block`` function: the fixed-shape
"accelerator invocation" that processes one DMA block, implemented as a
Pallas kernel (``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; see /opt/xla-example/README.md).

Block shapes are 8x128-aligned so the same kernels would tile cleanly for
VMEM on a real TPU. The CHStone accelerators are streaming math pipelines
(no matmul hot-spot), so the kernels target the VPU: element-wise lanes of
128, sublane-multiples of 8.
"""

from .adpcm import adpcm_block, ADPCM_BLOCK_SHAPE
from .dfadd import dfadd_block, DF_BLOCK_SHAPE
from .dfmul import dfmul_block
from .dfsin import dfsin_block
from .gsm import gsm_block, GSM_FRAME_SHAPE

__all__ = [
    "adpcm_block",
    "dfadd_block",
    "dfmul_block",
    "dfsin_block",
    "gsm_block",
    "ADPCM_BLOCK_SHAPE",
    "DF_BLOCK_SHAPE",
    "GSM_FRAME_SHAPE",
]
